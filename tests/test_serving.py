"""End-to-end serving tests: dispatcher, allocator, controller, faults."""

import collections

import pytest

from repro.core import PackratOptimizer
from repro.core.knapsack import InstanceGroup, PackratConfig
from repro.core.paper_profiles import INCEPTION_V3, RESNET50
from repro.serving import (AllocationError, ArrivalProcess, ContinuousPolicy,
                           ControllerConfig, EventLoop, PackratServer,
                           Request, ResourceAllocator, TabulatedBackend,
                           step_rate)
from repro.serving.dispatcher import Dispatcher, DispatcherConfig
from repro.serving.instance import WorkerInstance


def cfg_of(*groups):
    return PackratConfig(groups=tuple(InstanceGroup(*g) for g in groups),
                         latency=1.0)


# --------------------------------------------------------------------- #
# allocator (§3.4)
# --------------------------------------------------------------------- #
def test_allocator_round_robin_and_release():
    alloc = ResourceAllocator(16, domain_size=8)
    placements = alloc.allocate(cfg_of((4, 4, 8)))
    assert len(placements) == 4
    assert alloc.busy_units == 16
    # each instance within one domain (paper §7: NUMA locality)
    for p in placements:
        assert not alloc.spans_domains(p)
    alloc.release(placements)
    assert alloc.busy_units == 0


def test_allocator_oversubscription_for_active_passive():
    alloc = ResourceAllocator(8)
    a = alloc.allocate(cfg_of((1, 8, 16)))
    b = alloc.allocate(cfg_of((4, 2, 4)))       # passive set, occ 2
    assert alloc.oversubscribed_units == 8
    alloc.release(a)
    assert alloc.oversubscribed_units == 0
    alloc.release(b)


def test_allocator_at_most_one_spanning_instance():
    alloc = ResourceAllocator(8, domain_size=4, oversubscribe_factor=1)
    # a 5-thread instance cannot fit in a 4-unit domain: spans (allowed once)
    ps = alloc.allocate(cfg_of((1, 5, 8)))
    assert alloc.spans_domains(ps[0])
    # remaining units stay usable for domain-local instances
    ps2 = alloc.allocate(cfg_of((1, 3, 4)))
    assert not alloc.spans_domains(ps2[0])
    # two spanning instances are refused (paper §7: at most one)
    alloc2 = ResourceAllocator(8, domain_size=4, oversubscribe_factor=1)
    with pytest.raises(AllocationError):
        alloc2.allocate(cfg_of((2, 5, 8)))


def test_allocator_rejects_infeasible():
    alloc = ResourceAllocator(4, oversubscribe_factor=1)
    alloc.allocate(cfg_of((1, 4, 8)))
    with pytest.raises(AllocationError):
        alloc.allocate(cfg_of((1, 4, 8)))


# --------------------------------------------------------------------- #
# dispatcher (§3.5)
# --------------------------------------------------------------------- #
def _mk_dispatcher(loop, config, backend, responses):
    workers = [WorkerInstance(j, g.t, g.b, backend)
               for j, g in enumerate(
                   g for g in config.groups for _ in range(g.i))]
    return Dispatcher(loop, config, workers, responses.append,
                      DispatcherConfig(batch_timeout=0.05))


def test_batch_aggregation_and_partitioning():
    profile = RESNET50.profile(16, 64)
    backend = TabulatedBackend(profile)
    loop = EventLoop()
    responses = []
    config = PackratConfig(groups=(InstanceGroup(4, 4, 8),),
                           latency=profile[(4, 8)])
    disp = _mk_dispatcher(loop, config, backend, responses)
    for i in range(32):
        loop.at(0.001 * i, lambda i=i: disp.on_request(Request(i, 0.001 * i)))
    loop.run_until(10.0)
    assert len(responses) == 32
    # batch of 32 partitioned into 4 sub-batches of 8
    sizes = collections.Counter(r.batch_size for r in responses)
    assert sizes == {8: 32}
    assert len({r.instance_id for r in responses}) == 4


def test_partial_batch_timeout():
    profile = RESNET50.profile(16, 64)
    loop = EventLoop()
    responses = []
    config = PackratConfig(groups=(InstanceGroup(2, 8, 16),),
                           latency=profile[(8, 16)])
    disp = _mk_dispatcher(loop, config, TabulatedBackend(profile), responses)
    for i in range(5):   # much less than B=32
        loop.at(0.0, lambda i=i: disp.on_request(Request(i, 0.0)))
    loop.run_until(5.0)
    assert len(responses) == 5
    assert disp.timeouts_fired >= 1


def test_straggler_redispatch_on_failure():
    profile = RESNET50.profile(16, 64)
    loop = EventLoop()
    responses = []
    config = PackratConfig(groups=(InstanceGroup(2, 8, 8),),
                           latency=profile[(8, 8)])
    disp = _mk_dispatcher(loop, config, TabulatedBackend(profile), responses)
    for i in range(16):
        loop.at(0.0, lambda i=i: disp.on_request(Request(i, 0.0)))
    # fail worker 0 right after dispatch: its sub-batch must be re-issued
    loop.at(0.001, lambda: disp.instances[0].fail())
    loop.run_until(30.0)
    assert len(responses) == 16           # nothing lost
    assert disp.redispatches >= 1
    assert any(r.redispatched for r in responses)


def test_continuous_straggler_redispatch_on_failure():
    """Straggler re-dispatch works on per-instance queues too: a failed
    worker's in-flight sub-batch is re-issued by the watchdog."""
    profile = RESNET50.profile(16, 64)
    loop = EventLoop()
    responses = []
    config = PackratConfig(groups=(InstanceGroup(2, 8, 8),),
                           latency=profile[(8, 8)])
    workers = [WorkerInstance(j, g.t, g.b, TabulatedBackend(profile))
               for j, g in enumerate(
                   g for g in config.groups for _ in range(g.i))]
    disp = Dispatcher(loop, config, workers, responses.append,
                      DispatcherConfig(batch_timeout=0.05),
                      policy=ContinuousPolicy())
    for i in range(16):
        loop.at(0.0, lambda i=i: disp.on_request(Request(i, 0.0)))
    loop.at(0.001, lambda: disp.instances[0].fail())
    loop.run_until(30.0)
    ids = [r.request.id for r in responses]
    assert len(ids) == 16 and len(set(ids)) == 16      # nothing lost
    assert disp.redispatches >= 1
    assert any(r.redispatched for r in responses)


def test_continuous_worker_failure_respawn():
    """Heartbeat respawn under the continuous policy: queued work moves
    off the failed instance and every request completes exactly once."""
    profile = INCEPTION_V3.profile(16, 1024)
    opt = PackratOptimizer(profile)
    cfg8 = opt.solve(16, 8)
    loop = EventLoop()
    server = PackratServer(loop, total_units=16, optimizer=opt,
                           backend=TabulatedBackend(profile),
                           initial_batch=8,
                           config=ControllerConfig(
                               dispatch_policy="continuous"))
    arrivals = ArrivalProcess.uniform(lambda t: 0.8 * 8 / cfg8.latency, 15.0)
    for i, t in enumerate(arrivals):
        loop.at(t, (lambda i=i, t=t: server.submit(Request(i, t))))
    loop.at(5.0, lambda: server.inject_failure(0))
    loop.run_until(45.0)
    ids = [r.request.id for r in server.responses]
    assert len(ids) == len(arrivals) and len(set(ids)) == len(ids)
    assert all(not w.failed for w in server.dispatcher.instances)  # respawned


# --------------------------------------------------------------------- #
# controller end-to-end (Fig. 3 / Fig. 11)
# --------------------------------------------------------------------- #
def _run_server(rate_fn, duration, initial_batch=8, units=16, profile=None,
                drain=30.0, ccfg=None):
    profile = profile or INCEPTION_V3.profile(16, 1024)
    opt = PackratOptimizer(profile)
    loop = EventLoop()
    server = PackratServer(loop, total_units=units, optimizer=opt,
                           backend=TabulatedBackend(profile),
                           initial_batch=initial_batch, config=ccfg)
    arrivals = ArrivalProcess.uniform(rate_fn, duration)
    for i, t in enumerate(arrivals):
        loop.at(t, (lambda i=i, t=t: server.submit(Request(i, t))))
    loop.run_until(duration + drain)
    return server, arrivals


def test_steady_state_serves_everything():
    """Load matched to B=8 (the paper's Fig.-11 setup: 'the multi-instance
    configuration for B=8 ... correctly corresponds to the load generated
    by the client'): queue depth at dispatch ≈ 8 → no reconfiguration."""
    profile = INCEPTION_V3.profile(16, 1024)
    opt = PackratOptimizer(profile)
    cfg8 = opt.solve(16, 8)
    server, arrivals = _run_server(lambda t: 8 / cfg8.latency, 10.0)
    assert len(server.responses) == len(arrivals)
    # no spurious reconfig while traffic flows (post-drain scale-down ok)
    assert not [t for t, b, c in server.reconfig_log if 0 < t < 10.0]


def test_underload_scales_batch_down():
    """At fractional load, Packrat converges to a smaller B — smaller
    batches at low arrival rates minimize per-request latency (§3.8
    'scale up and scale down ... as request arrival rates change')."""
    profile = INCEPTION_V3.profile(16, 1024)
    opt = PackratOptimizer(profile)
    cfg8 = opt.solve(16, 8)
    server, arrivals = _run_server(lambda t: 0.5 * 8 / cfg8.latency, 20.0,
                                   drain=40.0)
    assert len(server.responses) == len(arrivals)
    assert server.estimator.current_batch < 8


def test_rate_step_triggers_reconfig_and_recovers():
    """Fig. 11: step in request rate → reconfiguration → latency recovers."""
    profile = INCEPTION_V3.profile(16, 1024)
    opt = PackratOptimizer(profile)
    cfg8, cfg64 = opt.solve(16, 8), opt.solve(16, 64)
    # high phase at 0.9× capacity so the overload backlog can drain
    rate = step_rate(8 / cfg8.latency, 0.9 * 64 / cfg64.latency, 8.0)
    # hold the stale config ~10 s like the paper ("we force the server to
    # not activate a change in batch size immediately") so the degraded
    # window is observable before the reconfiguration lands
    from repro.core import EstimatorConfig
    ccfg = ControllerConfig(estimator=EstimatorConfig(
        reconfigure_timeout=10.0))
    server, arrivals = _run_server(rate, 40.0, drain=60.0, ccfg=ccfg)
    assert len(server.responses) == len(arrivals)
    during = [(t, b) for t, b, c in server.reconfig_log if 0 < t <= 40.0]
    assert during, "no reconfiguration after the rate step"
    assert during[0][1] > 8                    # scaled the batch size up
    # latency in the final stable window beats the un-reconfigured window
    # right before the reconfiguration (paper Fig. 11: 1.54× at B=64)
    t_reconf = during[0][0]
    mid = [r.latency for r in server.responses
           if t_reconf - 2.0 < r.request.arrival < t_reconf]
    late = [r.latency for r in server.responses
            if 30.0 < r.request.arrival < 40.0]
    assert mid and late
    assert sorted(late)[len(late) // 2] < sorted(mid)[len(mid) // 2]


def test_no_downtime_during_reconfig():
    """Responses keep flowing in every 1 s window around a reconfig."""
    profile = INCEPTION_V3.profile(16, 1024)
    opt = PackratOptimizer(profile)
    cfg8, cfg64 = opt.solve(16, 8), opt.solve(16, 64)
    rate = step_rate(8 / cfg8.latency, 0.95 * 64 / cfg64.latency, 8.0)
    server, _ = _run_server(rate, 30.0, drain=60.0)
    done_by_s = collections.Counter(int(r.completion) for r in server.responses)
    for s in range(1, 28):
        assert done_by_s.get(s, 0) > 0, f"stall at t={s}s"


def test_worker_failure_respawn():
    profile = INCEPTION_V3.profile(16, 1024)
    opt = PackratOptimizer(profile)
    cfg8 = opt.solve(16, 8)
    loop = EventLoop()
    server = PackratServer(loop, total_units=16, optimizer=opt,
                           backend=TabulatedBackend(profile), initial_batch=8)
    arrivals = ArrivalProcess.uniform(lambda t: 0.8 * 8 / cfg8.latency, 15.0)
    for i, t in enumerate(arrivals):
        loop.at(t, (lambda i=i, t=t: server.submit(Request(i, t))))
    loop.at(5.0, lambda: server.inject_failure(0))
    loop.run_until(45.0)
    assert len(server.responses) == len(arrivals)     # nothing lost
    assert all(not w.failed for w in server.dispatcher.instances)  # respawned


def test_elastic_scale_down_reoptimizes():
    """Losing units re-runs the optimizer with T' (beyond-paper elastic)."""
    profile = INCEPTION_V3.profile(16, 1024)
    opt = PackratOptimizer(profile)
    loop = EventLoop()
    server = PackratServer(loop, total_units=16, optimizer=opt,
                           backend=TabulatedBackend(profile), initial_batch=32)
    before = server.apc.active
    loop.run_until(1.0)
    server.scale_units(8)
    loop.run_until(30.0)
    after = server.apc.active
    assert after.total_threads == 8
    assert after.groups != before.groups
