"""Beyond-paper extensions: SLO-constrained + multi-model allocation."""

import pytest

from repro.core import PackratOptimizer
from repro.core.multimodel import (ModelWorkload, MultiModelAllocator,
                                   solve_with_slo)
from repro.core.paper_profiles import BERT, INCEPTION_V3, RESNET50


def test_slo_picks_largest_feasible_batch():
    profile = RESNET50.profile(16, 1024)
    opt = PackratOptimizer(profile)
    got = solve_with_slo(opt, 16, latency_slo=0.300, max_batch=1024)
    assert got is not None
    B, cfg = got
    assert cfg.latency <= 0.300
    # the next larger batch must violate the SLO
    nxt = opt.solve(16, B * 2)
    assert nxt.latency > 0.300
    # throughput at the chosen point dominates all smaller batches
    for b in (1, 2, 4):
        if b < B:
            assert cfg.throughput >= opt.solve(16, b).throughput


def test_slo_infeasible_returns_none():
    profile = RESNET50.profile(16, 1024)
    opt = PackratOptimizer(profile)
    assert solve_with_slo(opt, 16, latency_slo=1e-6) is None


def test_multimodel_allocation_covers_all_models():
    workloads = [
        ModelWorkload("resnet", RESNET50.profile(16, 256), batch=32),
        ModelWorkload("bert", BERT.profile(16, 256), batch=64),
        ModelWorkload("inception", INCEPTION_V3.profile(16, 256), batch=16),
    ]
    alloc = MultiModelAllocator(workloads)
    placements = alloc.allocate(16)
    assert {p.name for p in placements} == {"resnet", "bert", "inception"}
    assert sum(p.units for p in placements) <= 16
    assert all(p.units >= 1 for p in placements)
    for p in placements:
        assert p.config.total_batch == {
            "resnet": 32, "bert": 64, "inception": 16}[p.name]


def test_multimodel_beats_even_split_makespan():
    """The λ-search allocation should not lose to a naive even split."""
    workloads = [
        ModelWorkload("heavy", INCEPTION_V3.profile(16, 1024), batch=256),
        ModelWorkload("light", BERT.profile(16, 1024), batch=8),
    ]
    alloc = MultiModelAllocator(workloads)
    placements = alloc.allocate(16)
    makespan = max(p.config.latency for p in placements)
    even = []
    for w in workloads:
        opt = PackratOptimizer(w.profile, allow_unused_threads=True)
        even.append(opt.solve(8, w.batch).latency)
    assert makespan <= max(even) + 1e-9
    # the heavy model should get the larger share
    by_name = {p.name: p.units for p in placements}
    assert by_name["heavy"] > by_name["light"]


def test_multimodel_single_workload_uses_pod():
    w = ModelWorkload("solo", RESNET50.profile(16, 256), batch=64)
    placements = MultiModelAllocator([w]).allocate(16)
    assert placements[0].units == 16   # leftover units folded back in
