"""Tests for the batch-size estimator (paper §3.8)."""

import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.core import BatchSizeEstimator, EstimatorConfig, floor_power_of_two


def test_floor_power_of_two():
    assert floor_power_of_two(1) == 1
    assert floor_power_of_two(1.9) == 1
    assert floor_power_of_two(2) == 2
    assert floor_power_of_two(3) == 2
    assert floor_power_of_two(64) == 64
    assert floor_power_of_two(65.2) == 64
    assert floor_power_of_two(0.3) == 1


@given(st.floats(min_value=0, max_value=1e9, allow_nan=False))
def test_floor_power_of_two_properties(x):
    p = floor_power_of_two(x)
    assert p >= 1 and (p & (p - 1)) == 0          # power of two
    if x >= 1:
        assert p <= x < 2 * p


@given(st.lists(st.floats(min_value=0, max_value=1e6, allow_nan=False),
                min_size=1, max_size=200))
@settings(max_examples=50, deadline=None)
def test_estimates_always_powers_of_two(depths):
    est = BatchSizeEstimator()
    for d in depths:
        b = est.observe(d)
        assert b >= 1 and (b & (b - 1)) == 0
    s = est.smoothed_batch()
    assert s >= 1 and (s & (s - 1)) == 0


def test_sustained_load_change_triggers_reconfig():
    """A step in arrival rate (Fig. 11) eventually changes B̃."""
    est = BatchSizeEstimator(EstimatorConfig(alpha=0.5, window=4,
                                             reconfigure_timeout=1.0),
                             initial_batch=8)
    for _ in range(20):
        est.observe(8)
    assert est.smoothed_batch() == 8
    assert est.should_reconfigure(now=10.0) is None
    # request spike: queue depth jumps to ~100 (floors to B̂=64)
    for _ in range(20):
        est.observe(100)
    new_b = est.should_reconfigure(now=20.0)
    assert new_b == 64
    est.commit(new_b)
    assert est.should_reconfigure(now=30.0) is None


def test_transient_spike_is_smoothed_away():
    """Two-level smoothing avoids flip-flop on short bursts (§3.8)."""
    est = BatchSizeEstimator(EstimatorConfig(alpha=0.25, window=8),
                             initial_batch=8)
    for _ in range(50):
        est.observe(8)
    # a 2-sample burst must not move the mode over an 8-deep window
    est.observe(512)
    est.observe(512)
    assert est.smoothed_batch() == 8


def test_reconfigure_rate_limited():
    est = BatchSizeEstimator(EstimatorConfig(reconfigure_timeout=5.0),
                             initial_batch=1)
    for _ in range(10):
        est.observe(32)
    assert est.should_reconfigure(now=0.0) is not None or True
    # first call consumed the timeout window; an immediate second check is
    # rate-limited even though B̃ != B still holds
    est2 = BatchSizeEstimator(EstimatorConfig(reconfigure_timeout=5.0),
                              initial_batch=1)
    for _ in range(10):
        est2.observe(32)
    first = est2.should_reconfigure(now=6.0)
    assert first == 32
    assert est2.should_reconfigure(now=6.5) is None   # < timeout later
    assert est2.should_reconfigure(now=12.0) == 32    # still uncommitted


def test_scale_down_also_works():
    """§3.8: estimator scales B down when arrival rates drop."""
    est = BatchSizeEstimator(EstimatorConfig(alpha=0.5, window=4,
                                             reconfigure_timeout=0.0),
                             initial_batch=64)
    for _ in range(30):
        est.observe(4)
    assert est.should_reconfigure(now=1.0) == 4


def test_ewma_tracks_mean():
    est = BatchSizeEstimator(EstimatorConfig(alpha=0.2))
    for _ in range(200):
        est.observe(100.0)
    assert abs(est.ewma - 100.0) < 1e-6


def test_bounds_respected():
    est = BatchSizeEstimator(EstimatorConfig(min_batch=2, max_batch=16))
    assert est.observe(0) >= 2
    for _ in range(50):
        b = est.observe(10**6)
    assert b <= 16


def test_invalid_config():
    with pytest.raises(ValueError):
        BatchSizeEstimator(EstimatorConfig(alpha=0.0))
    with pytest.raises(ValueError):
        BatchSizeEstimator(EstimatorConfig(window=0))
    est = BatchSizeEstimator()
    with pytest.raises(ValueError):
        est.observe(-1)
