"""Property tests: vectorized metrics aggregation vs per-record reference.

The vectorized kernels in :mod:`repro.serving.metrics`
(``vector_percentiles`` / ``vector_within_slo`` /
``vector_log2_ms_buckets``) and the block-ingestion path
(``MetricsCollector.on_response_block``) must be *value-identical* to
the per-record scalar implementations on every input — that is the
contract that lets the fast simulation core feed metrics in bulk
without perturbing a single reported number.

Seeded random streams always run; a hypothesis fuzz layer rides on top
when the library is available.
"""

import math
import random

import numpy as np
import pytest

from repro.serving.fastsim import ResponseBlock
from repro.serving.metrics import (MetricsCollector, buckets_to_histogram,
                                   log2_ms_bucket, log2_ms_histogram,
                                   nearest_rank, vector_log2_ms_buckets,
                                   vector_percentiles, vector_within_slo)
from repro.serving.simulator import Request, Response, Shed

QS = (1.0, 25.0, 50.0, 90.0, 95.0, 99.0, 99.9, 100.0)


def _random_latencies(seed, n):
    rng = random.Random(seed)
    kinds = [lambda: rng.uniform(0.0, 5.0),
             lambda: rng.expovariate(10.0),
             lambda: 2.0 ** rng.uniform(-12, 6) / 1e3,     # bucket edges
             lambda: math.ulp(1.0) * rng.randint(0, 4)]    # denormal-ish
    return [rng.choice(kinds)() for _ in range(n)]


# --------------------------------------------------------------------- #
# kernel equivalence
# --------------------------------------------------------------------- #
def _check_kernels(values, slo):
    ref_sorted = sorted(values)
    got = vector_percentiles(values, QS)
    for q, g in zip(QS, got):
        r = nearest_rank(ref_sorted, q)
        assert g == r or (math.isnan(g) and math.isnan(r)), (q, g, r)

    assert vector_within_slo(values, slo) == (
        len(values) if slo is None
        else sum(1 for v in values if v <= slo))
    assert vector_within_slo(values, None) == len(values)

    ref_buckets = {}
    for v in values:
        k = log2_ms_bucket(v)
        ref_buckets[k] = ref_buckets.get(k, 0) + 1
    assert vector_log2_ms_buckets(values) == ref_buckets
    assert (buckets_to_histogram(vector_log2_ms_buckets(values))
            == log2_ms_histogram(values))


@pytest.mark.parametrize("seed,n,slo",
                         [(0, 0, 0.5), (1, 1, 0.5), (2, 1, None),
                          (3, 7, 0.1), (4, 100, 0.5), (5, 1000, 1.0),
                          (6, 333, None), (7, 50, 0.0)])
def test_vector_kernels_match_reference_seeded(seed, n, slo):
    _check_kernels(_random_latencies(seed, n), slo)


def test_vector_kernels_bucket_edges():
    """Values one ulp either side of a power-of-two millisecond boundary
    land in the same bucket under both paths."""
    edges = []
    for e in range(-5, 8):
        ms = 2.0 ** e
        for v in (ms, math.nextafter(ms, 0.0), math.nextafter(ms, math.inf)):
            edges.append(v / 1e3)
    edges.append(0.0)
    _check_kernels(edges, 0.004)


def test_vector_percentiles_rejects_bad_q():
    with pytest.raises(ValueError):
        vector_percentiles([1.0], (0.0,))
    with pytest.raises(ValueError):
        vector_percentiles([1.0], (100.5,))
    with pytest.raises(ValueError):
        nearest_rank([1.0], 0.0)


def test_vector_kernels_empty_inputs():
    assert math.isnan(vector_percentiles([], (50.0,))[0])
    assert vector_within_slo([], 1.0) == 0
    assert vector_within_slo([], None) == 0
    assert vector_log2_ms_buckets([]) == {}


def test_vector_kernels_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=50, deadline=None)
    @given(values=st.lists(st.floats(min_value=0.0, max_value=1e6,
                                     allow_nan=False), max_size=200),
           slo=st.one_of(st.none(), st.floats(0.0, 10.0)))
    def check(values, slo):
        _check_kernels(values, slo)

    check()


# --------------------------------------------------------------------- #
# collector block path vs per-record path
# --------------------------------------------------------------------- #
def _random_blocks(seed, n_blocks):
    """(blocks, equivalent per-record Response list) pair."""
    rng = random.Random(seed)
    blocks, per_record = [], []
    next_id = 0
    for _ in range(n_blocks):
        n = rng.randint(1, 12)
        completion = rng.uniform(1.0, 50.0)
        arrivals = np.array(sorted(completion - rng.uniform(0.0, 2.0)
                                   for _ in range(n)))
        ids = np.arange(next_id, next_id + n, dtype=np.int64)
        next_id += n
        model = rng.choice(("resnet50", "bert"))
        redis = rng.random() < 0.2
        blocks.append(ResponseBlock(
            ids=ids, arrivals=arrivals, completion=completion,
            batch_size=n, instance_id=rng.randint(0, 3),
            redispatched=redis, model_id=model))
        for i in range(n):
            per_record.append(Response(
                Request(int(ids[i]), float(arrivals[i]), model_id=model),
                completion=completion, batch_size=n,
                instance_id=blocks[-1].instance_id,
                redispatched=redis, model_id=model))
    return blocks, per_record


def _collector():
    return MetricsCollector(slo_deadline=0.8,
                            slo_by_model={"bert": 1.5})


@pytest.mark.parametrize("seed,n_blocks", [(0, 1), (1, 5), (2, 40), (3, 13)])
def test_block_ingestion_matches_per_record(seed, n_blocks):
    blocks, per_record = _random_blocks(seed, n_blocks)

    a = _collector()
    a.on_requests(len(per_record) + 5, "resnet50")
    for r in per_record:
        a.on_response(r)

    b = _collector()
    for _ in range(len(per_record) + 5):
        b.on_request(Request(0, 0.0, model_id="resnet50"))
    for blk in blocks:
        b.on_response_block(blk)

    assert b.latencies == a.latencies          # same values, same order
    assert b.report(duration=10.0) == a.report(duration=10.0)
    assert b.worst_model_p95() == a.worst_model_p95()


def test_block_ingestion_empty_and_single_sample():
    empty = _collector()
    rep = empty.report(duration=1.0)
    assert rep["completed"] == 0 and rep["offered"] == 0
    assert rep["latency_ms"]["p99"] is None
    assert rep["latency_histogram"] == []
    assert rep["slo_attainment"] == 1.0

    one = _collector()
    one.on_requests(1)
    one.on_response_block(ResponseBlock(
        ids=np.array([0], dtype=np.int64), arrivals=np.array([0.25]),
        completion=0.75, batch_size=1, instance_id=0,
        redispatched=False, model_id="default"))
    rep = one.report(duration=1.0)
    assert rep["completed"] == 1
    assert rep["latency_ms"]["p50"] == rep["latency_ms"]["p99"] == 500.0
    assert rep["within_slo"] == 1 and rep["slo_attainment"] == 1.0


def test_all_shed_run_reports_zero_goodput():
    m = _collector()
    for i in range(10):
        req = Request(i, 0.1 * i)
        m.on_request(req)
        m.on_shed(Shed(req, time=0.1 * i, node_id="node-0", reason="queue"))
    rep = m.report(duration=1.0)
    assert rep["offered"] == rep["shed"] == 10
    assert rep["completed"] == 0 and rep["admitted"] == 0
    assert rep["shed_rate"] == 1.0
    assert rep["goodput_rps"] == 0.0 and rep["slo_attainment"] == 0.0
    assert rep["nodes"]["node-0"]["shed"] == 10


def test_on_requests_bulk_equals_repeated_on_request():
    a, b = _collector(), _collector()
    for _ in range(7):
        a.on_request(Request(0, 0.0, model_id="m"))
    b.on_requests(7, "m")
    b.on_requests(0, "m")
    b.on_requests(-3, "m")      # guard: no-op
    assert (a.offered, a.offered_by_model) == (b.offered, b.offered_by_model)


def test_collector_block_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000), n_blocks=st.integers(0, 20))
    def check(seed, n_blocks):
        blocks, per_record = _random_blocks(seed, n_blocks)
        a, b = _collector(), _collector()
        for r in per_record:
            a.on_response(r)
        for blk in blocks:
            b.on_response_block(blk)
        assert b.report(duration=5.0) == a.report(duration=5.0)

    check()
