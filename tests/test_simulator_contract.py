"""The event-loop ordering contract, pinned for both simulation cores.

The vectorized fast path (:class:`repro.serving.fastsim.FastLoop`)
byte-reproduces the legacy :class:`~repro.serving.simulator.EventLoop`
only because both implement the *identical* contract:

* events fire in ``(time, seq)`` order — same-timestamp ties break by
  scheduling sequence number;
* ``at()`` accepts timestamps up to ``PAST_EPSILON`` (1e-12) behind the
  clock (float round-off in deadline arithmetic) and rejects anything
  older;
* the clock never rewinds — a within-epsilon past event runs at ``now``;
* ``run_until(t)`` is inclusive of events at exactly ``t``.

Every test here runs against both loop classes.
"""

import math

import pytest

from repro.serving.fastsim import FastLoop
from repro.serving.simulator import PAST_EPSILON, EventLoop

LOOPS = [EventLoop, FastLoop]
LOOP_IDS = ["event-loop", "fast-loop"]


@pytest.fixture(params=LOOPS, ids=LOOP_IDS)
def loop(request):
    return request.param()


def test_past_epsilon_value_is_pinned():
    # the epsilon is part of the cross-core contract: changing it here
    # requires changing fastsim's trace-merge acceptance identically
    assert PAST_EPSILON == 1e-12


def test_same_timestamp_ties_fire_in_scheduling_order(loop):
    order = []
    for k in range(5):
        loop.at(1.0, (lambda k=k: order.append(k)))
    loop.at(0.5, lambda: order.append("early"))
    loop.run_until(1.0)
    assert order == ["early", 0, 1, 2, 3, 4]


def test_handler_scheduled_tie_fires_after_preexisting(loop):
    """An event scheduled *during* a timestamp-t handler for time t gets
    a later seq, so it fires after every pre-existing t event."""
    order = []

    def first():
        order.append("first")
        loop.at(2.0, lambda: order.append("nested"))

    loop.at(2.0, first)
    loop.at(2.0, lambda: order.append("second"))
    loop.run_until(2.0)
    assert order == ["first", "second", "nested"]


def test_at_accepts_within_epsilon_past(loop):
    loop.run_until(10.0)
    assert loop.now == 10.0
    fired = []
    loop.at(10.0 - PAST_EPSILON, lambda: fired.append(loop.now))
    loop.run_until(10.0)
    # the clock never rewinds: the event ran at now, not in the past
    assert fired == [10.0]
    assert loop.now == 10.0


def test_at_rejects_beyond_epsilon_past(loop):
    loop.run_until(10.0)
    with pytest.raises(ValueError):
        loop.at(10.0 - 1e-9, lambda: None)
    with pytest.raises(ValueError):
        loop.at(math.nextafter(10.0 - PAST_EPSILON, 0.0), lambda: None)


def test_run_until_is_inclusive_and_advances_clock(loop):
    fired = []
    loop.at(3.0, lambda: fired.append("at-3"))
    loop.at(math.nextafter(3.0, math.inf), lambda: fired.append("after-3"))
    loop.run_until(3.0)
    assert fired == ["at-3"]
    assert loop.now == 3.0           # clock reaches t_end even when idle
    loop.run_until(5.0)
    assert fired == ["at-3", "after-3"]
    assert loop.now == 5.0


def test_clock_monotone_through_epsilon_past_events(loop):
    """Deadline arithmetic that lands a hair behind the clock must not
    rewind ``now`` for later events."""
    seen = []

    def at_five():
        seen.append(loop.now)
        loop.at(loop.now - PAST_EPSILON, lambda: seen.append(loop.now))
        loop.at(loop.now + 1.0, lambda: seen.append(loop.now))

    loop.at(5.0, at_five)
    loop.run()
    assert seen == [5.0, 5.0, 6.0]


def test_schedule_is_relative_to_now(loop):
    fired = []
    loop.at(2.0, lambda: loop.schedule(1.5, lambda: fired.append(loop.now)))
    loop.run()
    assert fired == [3.5]


def test_run_drains_everything(loop):
    fired = []
    loop.at(1.0, lambda: loop.at(4.0, lambda: fired.append("late")))
    loop.at(2.0, lambda: fired.append("mid"))
    loop.run()
    assert fired == ["mid", "late"]
    assert loop.now == 4.0


# --------------------------------------------------------------------- #
# FastLoop-only: the trace merge obeys the same contract
# --------------------------------------------------------------------- #
def test_fastloop_trace_ties_respect_sequence_reservation():
    """add_trace reserves one seq per arrival at registration time, so a
    heap event scheduled before the trace wins a timestamp tie and one
    scheduled after loses it — indistinguishable from pre-scheduling
    every arrival with at()."""
    loop = FastLoop()
    order = []
    loop.at(1.0, lambda: order.append("heap-pre"))
    loop.add_trace([1.0, 1.0, 2.0], lambda i, t: order.append(f"arr{i}"))
    loop.at(1.0, lambda: order.append("heap-post"))
    loop.at(2.0, lambda: order.append("heap-post-2"))
    loop.run()
    assert order == ["heap-pre", "arr0", "arr1", "heap-post",
                     "arr2", "heap-post-2"]


def test_fastloop_epsilon_contract_with_trace_pending():
    """The epsilon acceptance is unchanged while a trace is draining."""
    loop = FastLoop()
    fired = []
    loop.add_trace([1.0, 5.0], lambda i, t: fired.append(t))
    loop.run_until(2.0)
    assert fired == [1.0] and loop.now == 2.0
    loop.at(2.0 - PAST_EPSILON, lambda: fired.append(loop.now))
    with pytest.raises(ValueError):
        loop.at(2.0 - 1e-9, lambda: None)
    loop.run()
    assert fired == [1.0, 2.0, 5.0]
    assert loop.pending_arrivals == 0
