"""Cluster fabric tests (ISSUE 5): routing, overload control,
drain/failover exactly-once, and the --nodes benchmark axis."""

import json

import pytest

from repro.core import PackratOptimizer
from repro.core.paper_profiles import RESNET50
from repro.launch import bench_serving
from repro.serving import (ClusterRouter, EventLoop, FabricConfig,
                           FabricNodeSpec, MetricsCollector, Request,
                           TabulatedBackend, TokenBucket)
from repro.serving.scenarios import fabric_events, get_scenario


UNITS = 8
PROFILE = RESNET50.profile(UNITS, 64)


def make_router(loop, n_nodes=3, *, slo=None, config=None,
                initial_batch=8):
    specs = [FabricNodeSpec(optimizer=PackratOptimizer(PROFILE),
                            backend=TabulatedBackend(PROFILE))
             for _ in range(n_nodes)]
    return ClusterRouter(loop, units_per_node=UNITS, specs=specs,
                         initial_batch=initial_batch, slo_deadline=slo,
                         config=config)


def node_capacity(batch=64):
    return PackratOptimizer(PROFILE).solve(UNITS, batch).throughput


def offer(loop, router, rate, duration, *, metrics=None, start=0.0):
    """Deterministic evenly-spaced arrivals at ``rate`` req/s."""
    n = int(rate * duration)
    for i in range(n):
        t = start + (i + 0.5) / rate
        if metrics is not None:
            metrics.on_request(Request(i, t))
        loop.at(t, (lambda i=i, t=t: router.submit(Request(i, t))))
    return n


def assert_exactly_once(router):
    ids = [r.request.id for r in router.responses]
    assert len(ids) == len(set(ids)), "duplicate delivery"
    shed_ids = {s.request.id for s in router.sheds}
    assert not (shed_ids & set(ids)), "shed request also delivered"


# --------------------------------------------------------------------- #
# token bucket
# --------------------------------------------------------------------- #
def test_token_bucket_rate_and_burst():
    tb = TokenBucket(rate_rps=10.0, burst=5.0)
    # burst drains immediately...
    assert sum(tb.take(0.0) for _ in range(10)) == 5
    # ...then refills at the configured rate
    assert not tb.take(0.05)          # only 0.5 tokens accrued
    assert tb.take(0.11)              # > 1 token since the last take
    # a long idle period caps at burst, not unbounded credit
    tb2 = TokenBucket(rate_rps=10.0, burst=5.0)
    for _ in range(5):
        tb2.take(0.0)
    assert sum(tb2.take(100.0) for _ in range(10)) == 5


def test_token_bucket_disabled_when_rate_nonpositive():
    tb = TokenBucket(rate_rps=0.0, burst=1.0)
    assert all(tb.take(0.0) for _ in range(100))


# --------------------------------------------------------------------- #
# routing
# --------------------------------------------------------------------- #
def test_p2c_routing_spreads_load_and_is_deterministic():
    def run():
        loop = EventLoop()
        router = make_router(loop, 3)
        offer(loop, router, rate=0.8 * node_capacity(32) * 3, duration=8.0)
        loop.run_until(30.0)
        return router

    a, b = run(), run()
    routed_a = [n.routed for n in a.nodes]
    assert all(r > 0 for r in routed_a), "a node never received work"
    assert routed_a == [n.routed for n in b.nodes]
    assert ([r.request.id for r in a.responses]
            == [r.request.id for r in b.responses])
    assert_exactly_once(a)


def test_router_requires_nodes_and_unique_ids():
    with pytest.raises(ValueError, match="at least one node"):
        ClusterRouter(EventLoop(), units_per_node=UNITS, specs=[],
                      initial_batch=8)
    specs = [FabricNodeSpec(optimizer=PackratOptimizer(PROFILE),
                            backend=TabulatedBackend(PROFILE),
                            node_id="dup") for _ in range(2)]
    with pytest.raises(ValueError, match="duplicate node_id"):
        ClusterRouter(EventLoop(), units_per_node=UNITS, specs=specs,
                      initial_batch=8)


# --------------------------------------------------------------------- #
# overload control: sheds are terminal and never pollute percentiles
# --------------------------------------------------------------------- #
def overloaded_run(duration=10.0):
    loop = EventLoop()
    slo = 4.0 * PackratOptimizer(PROFILE).solve(UNITS, 8).latency
    router = make_router(loop, 2, slo=slo)
    metrics = MetricsCollector(slo_deadline=slo)
    metrics.attach_fabric(router, until=duration + 30.0)
    offered = offer(loop, router, rate=3.0 * node_capacity() * 2,
                    duration=duration, metrics=metrics)
    loop.run_until(duration + 30.0)
    return router, metrics, offered, slo


def test_sheds_never_double_counted_in_latency_percentiles():
    router, metrics, offered, slo = overloaded_run()
    assert router.sheds, "overload produced no sheds"
    assert_exactly_once(router)
    rep = metrics.report(duration=10.0)
    # percentiles are admitted-only: every latency sample comes from a
    # delivered response, and delivered + shed + incomplete = offered
    assert rep["completed"] == len(router.responses)
    assert len(metrics.latencies) == rep["completed"]
    assert rep["shed"] == len(router.sheds)
    assert rep["offered"] == offered
    assert (rep["completed"] + rep["shed"] + rep["incomplete"]
            == rep["offered"])
    assert rep["admitted"] == rep["offered"] - rep["shed"]
    assert 0.0 < rep["shed_rate"] < 1.0
    # sheds count against goodput/attainment (honest overload metrics)
    assert rep["slo_attainment"] < 1.0
    # admitted percentiles stay bounded because shedding bounds queues
    assert rep["latency_ms"]["p95"] is not None


def test_degrade_engages_before_queue_sheds():
    router, metrics, _, _ = overloaded_run()
    enters = [t for t, _, ev in router.degrade_log if ev == "enter"]
    assert enters, "overload never engaged degrade mode"
    queue_sheds = [s.time for s in router.sheds if s.reason == "queue"]
    if queue_sheds:
        assert min(enters) <= min(queue_sheds), \
            "queue shedding started before batch floors were degraded"
    # degraded nodes pin their estimator to the degrade batch
    for _, node_id, ev in router.degrade_log:
        node = next(n for n in router.nodes if n.node_id == node_id)
        if ev == "enter":
            assert node.b_deg >= 1


def test_degrade_mode_exits_after_overload_clears():
    loop = EventLoop()
    slo = 4.0 * PackratOptimizer(PROFILE).solve(UNITS, 8).latency
    router = make_router(loop, 2, slo=slo)
    # 5 s of 3x overload, then 15 s of near-idle traffic
    offer(loop, router, rate=3.0 * node_capacity() * 2, duration=5.0)
    n0 = int(3.0 * node_capacity() * 2 * 5.0)
    for k in range(10):
        t = 6.0 + k * 1.0
        loop.at(t, (lambda i=n0 + k, t=t: router.submit(Request(i, t))))
    loop.run_until(60.0)
    events = [ev for _, _, ev in router.degrade_log]
    assert "enter" in events and "exit" in events
    assert not any(n.degraded for n in router.nodes)


# --------------------------------------------------------------------- #
# drain / failure: exactly-once across re-routing
# --------------------------------------------------------------------- #
def faulted_run(action, duration=10.0, dispatch="sync"):
    loop = EventLoop()
    cfg = FabricConfig()
    cfg.controller.dispatch_policy = dispatch
    router = make_router(loop, 3, config=cfg)
    rate = 0.7 * node_capacity(32) * 3
    offered = offer(loop, router, rate=rate, duration=duration)
    # fire mid-run, while batches are in flight on every node
    loop.at(0.45 * duration, lambda: action(router))
    loop.run_until(duration + 30.0)
    return router, offered


@pytest.mark.parametrize("dispatch", ["sync", "continuous"])
def test_node_failure_mid_batch_reroutes_without_duplicates(dispatch):
    router, offered = faulted_run(lambda r: r.fail_node(1),
                                  dispatch=dispatch)
    assert_exactly_once(router)
    dead = router.nodes[1]
    assert dead.dead and dead.server.halted
    assert not dead.pending, "failed node still holds undelivered ids"
    # every admitted request was delivered by a surviving node
    assert len(router.responses) + len(router.sheds) == offered
    assert router.duplicates_suppressed == 0
    # the dead node delivered nothing after the failure instant: its
    # in-flight batches complete on failed workers, which never deliver
    from_dead = [r for r in router.responses if r.node_id == "node1"]
    assert from_dead, "node1 served nothing before the failure"
    assert max(r.completion for r in from_dead) <= 4.5 + 1e-9
    fleet = router.fleet_report(router.loop.now)
    assert fleet["per_node"]["node1"]["dead"] is True


@pytest.mark.parametrize("dispatch", ["sync", "continuous"])
def test_drain_mid_batch_preserves_exactly_once(dispatch):
    router, offered = faulted_run(lambda r: r.drain_node(0),
                                  dispatch=dispatch)
    assert_exactly_once(router)
    drained = router.nodes[0]
    assert drained.draining and not drained.dead
    # in-flight work delivered from the draining node; undispatched
    # work moved — nothing lost either way
    assert len(router.responses) + len(router.sheds) == offered
    assert not drained.pending
    # after the drain point, no *new* requests were routed to node0:
    # everything it delivered arrived before the drain
    routed_after = [r for r in router.responses
                    if r.node_id == "node0" and r.request.arrival > 4.5]
    assert not routed_after


def test_failed_node_excluded_from_routing():
    loop = EventLoop()
    router = make_router(loop, 2)
    loop.at(1.0, lambda: router.fail_node(0))
    rate = 0.5 * node_capacity(32)
    n = int(rate * 10.0)
    for i in range(n):
        t = 2.0 + i / rate
        loop.at(t, (lambda i=i, t=t: router.submit(Request(i, t))))
    loop.run_until(60.0)
    assert router.nodes[0].routed == 0
    assert router.nodes[1].routed == len(router.responses)
    assert_exactly_once(router)


def test_all_nodes_dead_sheds_with_no_node_reason():
    loop = EventLoop()
    router = make_router(loop, 2)
    router.fail_node(0)
    router.fail_node(1)
    router.submit(Request(0, 0.0))
    assert not router.responses
    assert [s.reason for s in router.sheds] == ["no-node"]


# --------------------------------------------------------------------- #
# benchmark axis (--nodes)
# --------------------------------------------------------------------- #
FAB_KW = dict(model=RESNET50, nodes=3, units_per_node=8, duration=10.0,
              seed=0, initial_batch=4, max_batch=64, slo_factor=4.0,
              reconfigure_timeout=2.0)


def test_run_fabric_scenario_reports_all_rows_and_fleet():
    result = bench_serving.run_fabric_scenario(
        get_scenario("steady-poisson"), **FAB_KW)
    assert result["policies"] == ["single_fat", "single_packrat", "fabric"]
    for key in result["policies"]:
        rep = result[key]
        assert rep["latency_ms"]["p95"] is not None
        assert "shed" in rep and "shed_rate" in rep
    fab = result["fabric"]
    assert set(fab["fleet"]["per_node"]) == {"node0", "node1", "node2"}
    for row in fab["fleet"]["per_node"].values():
        assert row["instances"], "missing per-instance breakdown"
    # single-server rows carry no fleet/nodes sections
    assert "fleet" not in result["single_fat"]
    assert "nodes" not in result["single_fat"]


def test_run_fabric_scenario_is_deterministic():
    a = bench_serving.run_fabric_scenario(get_scenario("flash-overload"),
                                          **FAB_KW)
    b = bench_serving.run_fabric_scenario(get_scenario("flash-overload"),
                                          **FAB_KW)
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)


def test_node_failure_scenario_applies_fabric_event():
    assert fabric_events("node-failure")
    assert fabric_events("steady-poisson") == ()
    result = bench_serving.run_fabric_scenario(
        get_scenario("node-failure"), **FAB_KW)
    fab = result["fabric"]
    assert fab["fleet"]["events"] == [
        {"t": pytest.approx(4.0), "action": "fail", "node": 1}]
    assert fab["fleet"]["per_node"]["node1"]["dead"] is True
    assert fab["fleet"]["duplicates_suppressed"] == 0
    # nothing admitted was lost to the failure
    assert fab["incomplete"] == 0


def test_acceptance_fabric_beats_single_fat_node_under_flash_overload():
    """ISSUE 5 acceptance: on the seeded flash-crowd overload trace the
    3-node fabric with admission control keeps admitted-request p95
    within the SLO with a bounded shed rate, while the single-fat-node
    baseline on the identical trace violates it."""
    result = bench_serving.run_fabric_scenario(
        get_scenario("flash-overload"),
        **dict(FAB_KW, duration=20.0, reconfigure_timeout=5.0))
    slo_ms = result["slo_deadline_ms"]
    fab, fat = result["fabric"], result["single_fat"]
    assert fab["latency_ms"]["p95"] <= slo_ms
    assert 0.0 < fab["shed_rate"] <= 0.6          # bounded, not panicked
    assert fat["latency_ms"]["p95"] > slo_ms
    assert fat["shed"] == 0                       # baseline never sheds
    # admitted-only accounting stayed consistent
    assert (fab["completed"] + fab["shed"] + fab["incomplete"]
            == fab["offered"] == result["offered"])


def test_cli_nodes_1_matches_single_node_path_byte_for_byte(tmp_path):
    args = ["--scenario", "steady-poisson", "--model", "resnet50",
            "--units", "8", "--duration", "8", "--initial-batch", "4",
            "--max-batch", "64", "--dispatch", "sync"]
    out_default = tmp_path / "default.json"
    out_nodes1 = tmp_path / "nodes1.json"
    assert bench_serving.main(args + ["--out", str(out_default)]) == 0
    assert bench_serving.main(args + ["--nodes", "1",
                                      "--out", str(out_nodes1)]) == 0
    assert out_default.read_bytes() == out_nodes1.read_bytes()


def test_cli_nodes_3_writes_fleet_report(tmp_path):
    out = tmp_path / "fabric.json"
    rc = bench_serving.main([
        "--nodes", "3", "--units", "8", "--model", "resnet50",
        "--scenario", "overload", "--duration", "8",
        "--initial-batch", "4", "--max-batch", "64",
        "--dispatch", "sync", "--out", str(out)])
    assert rc == 0
    report = json.loads(out.read_text())
    assert report["schema_version"] == bench_serving.SCHEMA_VERSION
    assert report["nodes"] == 3 and report["total_units"] == 24
    sc = report["scenarios"]["overload"]
    assert sc["fabric"]["shed"] > 0               # sustained overload sheds
    assert sc["fabric"]["fleet"]["nodes"] == 3


def test_cli_nodes_rejects_incompatible_flags():
    with pytest.raises(SystemExit):
        bench_serving.main(["--nodes", "0"])
    with pytest.raises(SystemExit):
        bench_serving.main(["--nodes", "2", "--models", "resnet50,bert"])
    with pytest.raises(SystemExit):
        bench_serving.main(["--nodes", "2", "--execution", "real"])
