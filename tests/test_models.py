"""Per-architecture smoke + correctness tests (deliverable f).

Every assigned architecture instantiates a REDUCED config of the same
family and runs: one forward pass (shape + finiteness), one train step
(loss finite, params update), and the KV-cache equivalence invariant
(prefill + decode_step == full forward position-by-position).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs, applicable_shapes, get_config
from repro.configs.base import LONG_500K, SHAPES
from repro.models import build_model
from repro.models.lm import apply_head, param_count
from repro.training import AdamWConfig, TrainConfig, init_adamw, make_train_step
from repro.training.train_loop import shift_labels

ARCHS = sorted(all_configs())


def make_batch(cfg, B, S, key=0, with_labels=False):
    tok = jax.random.randint(jax.random.PRNGKey(key), (B, S), 0,
                             cfg.vocab_size)
    batch = {"tokens": tok}
    text_start = 0
    if cfg.frontend and cfg.frontend.kind == "vision":
        P = cfg.frontend.n_prefix_tokens
        batch["tokens"] = tok[:, : S - P]
        batch["vision_embeds"] = jax.random.normal(
            jax.random.PRNGKey(key + 1), (B, P, cfg.d_model), jnp.bfloat16)
        text_start = P
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(key + 2), (B, S, cfg.d_model), jnp.bfloat16)
    if with_labels:
        batch["labels"] = shift_labels(tok)
    return batch, text_start


@pytest.fixture(scope="module")
def reduced_models():
    cache = {}

    def get(name, **kw):
        key = (name, tuple(sorted(kw.items())))
        if key not in cache:
            cfg = all_configs()[name].reduced(**kw)
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[key] = (cfg, model, params)
        return cache[key]

    return get


# --------------------------------------------------------------------- #
# smoke: forward
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, reduced_models):
    cfg, model, params = reduced_models(arch)
    B, S = 2, 32
    batch, _ = make_batch(cfg, B, S)
    h = model.forward(params, batch)
    assert h.shape == (B, S, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h.astype(jnp.float32))))
    logits = model.logits(params, h)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert logits.dtype == jnp.float32


# --------------------------------------------------------------------- #
# smoke: one train step on CPU, no NaNs, params move
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch, reduced_models):
    cfg, model, params = reduced_models(arch)
    tcfg = TrainConfig(adamw=AdamWConfig(learning_rate=1e-3, warmup_steps=1,
                                         decay_steps=10))
    step = jax.jit(make_train_step(cfg, tcfg))
    opt = init_adamw(tcfg.adamw, params)
    batch, _ = make_batch(cfg, 2, 32, with_labels=True)
    new_params, new_opt, metrics = step(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert int(new_opt.step) == 1
    # at least one leaf changed
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(new_params)))
    assert moved


# --------------------------------------------------------------------- #
# KV-cache equivalence: prefill + decode == forward
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch, reduced_models):
    cfg, model, params = reduced_models(arch, dtype="float32")
    B, S, n_dec = 2, 24, 4
    batch, text_start = make_batch(cfg, B, S)
    full_logits = apply_head(params, model.forward(params, batch), cfg)

    n_pre = S - n_dec
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, : n_pre - text_start]
    logits_last, cache = model.prefill(params, pre, max_len=S)
    scale = float(jnp.max(jnp.abs(full_logits))) + 1e-6
    errs = [float(jnp.max(jnp.abs(logits_last[:, 0]
                                  - full_logits[:, n_pre - 1])))]
    for i in range(n_pre, S):
        tok = batch["tokens"][:, i - text_start: i - text_start + 1]
        logits, cache = model.decode_step(params, cache, tok, jnp.int32(i))
        errs.append(float(jnp.max(jnp.abs(logits[:, 0] - full_logits[:, i]))))
    assert max(errs) / scale < 2e-4, errs


# --------------------------------------------------------------------- #
# windowed caches: gemma3 ring buffer stays faithful past the window
# --------------------------------------------------------------------- #
def test_ring_buffer_decode_beyond_window():
    cfg = get_config("gemma3-1b").reduced(dtype="float32")
    assert cfg.sliding_window and cfg.sliding_window < 80
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 96   # > window
    batch, _ = make_batch(cfg, B, S)
    full_logits = apply_head(params, model.forward(params, batch), cfg)
    n_pre = S - 8
    logits_last, cache = model.prefill(
        params, {"tokens": batch["tokens"][:, :n_pre]}, max_len=S)
    scale = float(jnp.max(jnp.abs(full_logits))) + 1e-6
    errs = [float(jnp.max(jnp.abs(logits_last[:, 0]
                                  - full_logits[:, n_pre - 1])))]
    for i in range(n_pre, S):
        logits, cache = model.decode_step(
            params, cache, batch["tokens"][:, i:i + 1], jnp.int32(i))
        errs.append(float(jnp.max(jnp.abs(logits[:, 0] - full_logits[:, i]))))
    assert max(errs) / scale < 2e-4


# --------------------------------------------------------------------- #
# scan_layers must not change the math
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("arch", ["llama3-8b", "gemma3-1b", "deepseek-v2-236b",
                                  "recurrentgemma-9b", "seamless-m4t-medium"])
def test_scan_equals_unrolled(arch):
    cfg_u = all_configs()[arch].reduced(n_repeats=3, dtype="float32")
    cfg_s = cfg_u.with_overrides(scan_layers=True)
    model_u, model_s = build_model(cfg_u), build_model(cfg_s)
    params_u = model_u.init(jax.random.PRNGKey(0))

    # restack unrolled params into the scanned layout
    def stack(position):
        return jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs),
            *[params_u["pattern"][r][position] for r in range(cfg_u.n_repeats)])

    params_s = dict(params_u)
    params_s["pattern"] = [stack(j) for j in range(len(cfg_u.pattern))]
    batch, _ = make_batch(cfg_u, 2, 16)
    hu = model_u.forward(params_u, batch)
    hs = model_s.forward(params_s, batch)
    np.testing.assert_allclose(np.asarray(hu, np.float32),
                               np.asarray(hs, np.float32),
                               atol=1e-4, rtol=1e-4)


# --------------------------------------------------------------------- #
# config registry / shape applicability (assignment bookkeeping)
# --------------------------------------------------------------------- #
def test_all_ten_archs_registered():
    from repro.configs.archs import ARCH_IDS
    assert len(ARCH_IDS) == 10
    for a in ARCH_IDS:
        assert a in all_configs()


def test_published_dimensions():
    """Exact dims from the assignment table."""
    expect = {
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
        "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129280),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "mamba2-130m": (24, 768, 24, 1, 0, 50280),
    }
    for arch, (L, d, H, Hkv, ff, V) in expect.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.n_heads == H, arch
        assert cfg.n_kv_heads == Hkv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == V, arch
    # seamless: 12 enc + 12 dec
    sm = get_config("seamless-m4t-medium")
    assert sm.n_layers == 24 and sm.n_repeats == 12
    assert sm.d_model == 1024 and sm.vocab_size == 256206


def test_long_context_applicability():
    """long_500k only for sub-quadratic archs (DESIGN.md §4)."""
    eligible = {a for a, c in all_configs().items()
                if any(s.name == "long_500k" for s in applicable_shapes(c))}
    assert eligible == {"mamba2-130m", "recurrentgemma-9b", "gemma3-1b"}


def test_moe_active_params_below_total():
    from repro.models.lm import active_param_count
    cfg = get_config("deepseek-v2-236b").reduced()
    model = build_model(cfg)
    p = model.param_specs()
    assert active_param_count(cfg, p) < param_count(p)


def test_param_counts_match_published_scale():
    """Full configs hit the advertised parameter counts (±15%)."""
    import math
    expected = {"llama3-8b": 8.0e9, "deepseek-v2-236b": 236e9,
                "deepseek-v3-671b": 671e9, "mamba2-130m": 130e6,
                "stablelm-12b": 12.1e9, "recurrentgemma-9b": 9e9}
    for arch, n in expected.items():
        model = build_model(get_config(arch))
        got = param_count(model.param_specs())
        assert abs(got - n) / n < 0.15, (arch, got, n)
