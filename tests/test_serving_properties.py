"""Hypothesis property tests for the serving runtime's core invariants."""

import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.core import PackratOptimizer
from repro.core.paper_profiles import RESNET50
from repro.serving import (ArrivalProcess, EventLoop, PackratServer, Request,
                           TabulatedBackend)

PROFILE = RESNET50.profile(16, 1024)


@settings(max_examples=15, deadline=None)
@given(
    rate_frac=st.floats(min_value=0.2, max_value=1.2),
    initial_batch=st.sampled_from([4, 8, 16, 32]),
    failures=st.lists(st.tuples(st.floats(1.0, 8.0), st.integers(0, 3)),
                      max_size=3),
)
def test_no_request_lost_under_failures(rate_frac, initial_batch, failures):
    """Every submitted request completes exactly once, for arbitrary loads
    (including overload) and arbitrary mid-run worker failures."""
    opt = PackratOptimizer(PROFILE)
    cfg = opt.solve(16, initial_batch)
    loop = EventLoop()
    server = PackratServer(loop, total_units=16, optimizer=opt,
                           backend=TabulatedBackend(PROFILE),
                           initial_batch=initial_batch)
    rate = rate_frac * initial_batch / cfg.latency
    arrivals = ArrivalProcess.uniform(lambda t: rate, 10.0)
    for i, t in enumerate(arrivals):
        loop.at(t, (lambda i=i, t=t: server.submit(Request(i, t))))
    for t, idx in failures:
        loop.at(t, (lambda idx=idx: server.inject_failure(idx)))
    loop.run_until(10.0 + 120.0)
    ids = [r.request.id for r in server.responses]
    assert len(ids) == len(arrivals), "requests lost"
    assert len(set(ids)) == len(ids), "duplicate completions"
    # latencies are physical: completion after arrival
    assert all(r.latency >= 0 for r in server.responses)


@settings(max_examples=10, deadline=None)
@given(units=st.sampled_from([4, 8, 14, 16]),
       batch=st.sampled_from([8, 32, 128]))
def test_dispatcher_config_constraints_always_hold(units, batch):
    """Whatever the estimator does, the live config satisfies Eq. 2."""
    opt = PackratOptimizer(RESNET50.profile(units, 1024))
    loop = EventLoop()
    server = PackratServer(loop, total_units=units, optimizer=opt,
                           backend=TabulatedBackend(
                               RESNET50.profile(units, 1024)),
                           initial_batch=batch)
    cfg = opt.solve(units, batch)
    rate = batch / cfg.latency
    for i, t in enumerate(ArrivalProcess.uniform(lambda t: rate, 5.0)):
        loop.at(t, (lambda i=i, t=t: server.submit(Request(i, t))))
    checks = []

    def check():
        c = server.apc.serving_config
        checks.append((c.total_threads, c.total_batch))
        assert c.total_threads <= units
        loop.schedule(0.5, check)

    loop.schedule(0.25, check)
    loop.run_until(20.0)
    assert checks
