"""Differential harness: the vectorized fast path vs the event-loop oracle.

Every registered scenario × dispatch policy × node count is replayed
through both simulation cores and must produce a *byte-identical*
response timeline (every observable field of every delivery, in
delivery order) — plus identical metrics reports on the single-node
matrix, where the fast path delivers completions as blocks.

The pinned PR 2 / PR 3 golden hashes are additionally reproduced
through the FastPlane, so the fast path is chained to the same
pre-refactor oracle as the legacy engine.
"""

import pytest

from repro.core import PackratOptimizer
from repro.core.paper_profiles import (PAPER_MODELS, RESNET50,
                                       fidelity_ladder)
from repro.serving import (ControllerConfig, EventLoop, MultiModelServer,
                           PackratServer, Request, TabulatedBackend,
                           TenantSpec)
from repro.serving.dispatcher import DispatcherConfig
from repro.serving.fabric import (ClusterRouter, FabricConfig,
                                  FabricNodeSpec, feed_fabric_trace)
from repro.serving.fastsim import (ColumnQueue, FastContinuousDispatcher,
                                   FastLoop, FastPlane, FastSyncDispatcher,
                                   ResponseBlock, ResponseLog,
                                   feed_multi_model_trace,
                                   feed_single_model_trace)
from repro.serving.metrics import MetricsCollector
from repro.serving.scenarios import (MultiModelScenarioContext,
                                     ScenarioContext, fabric_events,
                                     get_mm_scenario, get_scenario,
                                     list_mm_scenarios, list_scenarios)
from repro.serving.workloads import PoissonWorkload

from oracles import (GOLDEN_SHA256, MM_GOLDEN_SHA256, golden_run,
                     mm_golden_run, response_tuples, single_model_timeline,
                     timeline_digest)

# run shape: small enough that the whole matrix stays in tier-1 budget,
# large enough that every scenario produces real dispatch/shed activity
UNITS = 8
MAX_BATCH = 64
DURATION = 6.0
DRAIN = 30.0
SLO = 1.0

PROFILE8 = RESNET50.profile(UNITS, MAX_BATCH)
OPT8 = PackratOptimizer(PROFILE8)

NODES = 3
NODE_UNITS = 4
NODE_PROFILE = RESNET50.profile(NODE_UNITS, MAX_BATCH)
FLEET_OPT = PackratOptimizer(RESNET50.profile(NODES * NODE_UNITS, MAX_BATCH))

SCENARIO_NAMES = [s.name for s in list_scenarios()]
MM_SCENARIO_NAMES = [s.name for s in list_mm_scenarios()]
DISPATCHES = ("sync", "continuous")

_ARRIVAL_CACHE = {}


def _arrivals(name, *, fleet):
    key = (name, fleet)
    if key not in _ARRIVAL_CACHE:
        threads = NODES * NODE_UNITS if fleet else UNITS
        opt = FLEET_OPT if fleet else OPT8
        ctx = ScenarioContext(threads=threads, optimizer=opt,
                              duration=DURATION, seed=0,
                              max_total_batch=threads * MAX_BATCH)
        wl = get_scenario(name).build(ctx)
        _ARRIVAL_CACHE[key] = wl.arrivals(DURATION, seed=0)
    return _ARRIVAL_CACHE[key]


def _loop(engine):
    return EventLoop() if engine == "event" else FastLoop()


# --------------------------------------------------------------------- #
# single node: every scenario × dispatch policy, responses AND report
# --------------------------------------------------------------------- #
def _run_single_node(arrivals, dispatch, engine):
    loop = _loop(engine)
    server = PackratServer(loop, total_units=UNITS, optimizer=OPT8,
                           backend=TabulatedBackend(PROFILE8),
                           initial_batch=8,
                           config=ControllerConfig(dispatch_policy=dispatch))
    metrics = MetricsCollector(slo_deadline=SLO)
    metrics.attach(server, sample_interval=0.25, until=DURATION + DRAIN)
    if engine == "fast":
        metrics.on_requests(len(arrivals))
        feed_single_model_trace(server, arrivals)
    else:
        for i, t in enumerate(arrivals):
            metrics.on_request(Request(i, t))
            loop.at(t, (lambda i=i, t=t: server.submit(Request(i, t))))
    loop.run_until(DURATION + DRAIN)
    return (response_tuples(server.responses),
            metrics.report(duration=DURATION))


@pytest.mark.parametrize("dispatch", DISPATCHES)
@pytest.mark.parametrize("name", SCENARIO_NAMES)
def test_single_node_differential(name, dispatch):
    arrivals = _arrivals(name, fleet=False)
    event_tl, event_rep = _run_single_node(arrivals, dispatch, "event")
    fast_tl, fast_rep = _run_single_node(arrivals, dispatch, "fast")
    assert event_tl, f"scenario {name} produced no responses"
    assert fast_tl == event_tl
    assert fast_rep == event_rep


# --------------------------------------------------------------------- #
# 3-node fabric: every scenario × dispatch policy, responses AND sheds
# --------------------------------------------------------------------- #
def _run_fabric(arrivals, dispatch, engine, events):
    ccfg = ControllerConfig()
    ccfg.estimator.max_batch = MAX_BATCH
    ccfg.dispatch_policy = dispatch
    fcfg = FabricConfig(controller=ccfg, p2c_seed=0)
    specs = [FabricNodeSpec(optimizer=PackratOptimizer(NODE_PROFILE),
                            backend=TabulatedBackend(NODE_PROFILE))
             for _ in range(NODES)]
    loop = _loop(engine)
    router = ClusterRouter(loop, units_per_node=NODE_UNITS, specs=specs,
                           initial_batch=8, slo_deadline=SLO, config=fcfg)
    sheds = []
    router.on_shed = sheds.append
    if engine == "fast":
        feed_fabric_trace(router, arrivals)
    else:
        for i, t in enumerate(arrivals):
            loop.at(t, (lambda i=i, t=t: router.submit(Request(i, t))))
    for ev in events:
        action = {"fail": router.fail_node,
                  "drain": router.drain_node}[ev.action]
        loop.at(ev.at_frac * DURATION,
                (lambda action=action, ev=ev: action(ev.node)))
    loop.run_until(DURATION + DRAIN)
    if engine == "fast":
        # the trace machinery must have accounted for every arrival
        assert (router.fast_absorbed + router.fast_one_by_one
                == len(arrivals))
    shed_tl = [(s.request.id, round(s.time, 9), s.node_id, s.reason)
               for s in sheds]
    return response_tuples(router.responses), shed_tl


@pytest.mark.parametrize("dispatch", DISPATCHES)
@pytest.mark.parametrize("name", SCENARIO_NAMES)
def test_fabric_three_node_differential(name, dispatch):
    arrivals = _arrivals(name, fleet=True)
    events = fabric_events(name)
    event_tl, event_shed = _run_fabric(arrivals, dispatch, "event", events)
    fast_tl, fast_shed = _run_fabric(arrivals, dispatch, "fast", events)
    assert event_tl, f"scenario {name} produced no responses"
    assert fast_tl == event_tl
    assert fast_shed == event_shed


# --------------------------------------------------------------------- #
# fidelity-ladder fabric: overload scenarios × dispatch × fleet size,
# responses (rung-tagged), sheds AND the degrade log, fast vs event
# --------------------------------------------------------------------- #
def _run_fidelity_fabric(arrivals, dispatch, engine, n_nodes):
    ccfg = ControllerConfig()
    ccfg.estimator.max_batch = MAX_BATCH
    ccfg.dispatch_policy = dispatch
    fcfg = FabricConfig(controller=ccfg, p2c_seed=0)
    specs = [FabricNodeSpec(
        optimizer=PackratOptimizer(NODE_PROFILE),
        backend=TabulatedBackend(NODE_PROFILE),
        ladder=fidelity_ladder(RESNET50, NODE_UNITS, MAX_BATCH))
        for _ in range(n_nodes)]
    loop = _loop(engine)
    router = ClusterRouter(loop, units_per_node=NODE_UNITS, specs=specs,
                           initial_batch=8, slo_deadline=SLO, config=fcfg)
    if engine == "fast":
        feed_fabric_trace(router, arrivals)
    else:
        for i, t in enumerate(arrivals):
            loop.at(t, (lambda i=i, t=t: router.submit(Request(i, t))))
    loop.run_until(DURATION + DRAIN)
    if engine == "fast":
        assert (router.fast_absorbed + router.fast_one_by_one
                == len(arrivals))
    shed_tl = [(s.request.id, round(s.time, 9), s.node_id, s.reason)
               for s in router.sheds]
    degrade_tl = [(round(t, 9), nid, ev)
                  for t, nid, ev in router.degrade_log]
    return response_tuples(router.responses), shed_tl, degrade_tl


@pytest.mark.parametrize("n_nodes", (1, 3))
@pytest.mark.parametrize("dispatch", DISPATCHES)
@pytest.mark.parametrize("name", ("overload", "flash-overload"))
def test_fidelity_fabric_differential(name, dispatch, n_nodes):
    # the fleet-scaled trace makes the 1-node row a 3×-overloaded node:
    # deep ladder descent, batch-floor engagement, and queue sheds
    arrivals = _arrivals(name, fleet=True)
    ev = _run_fidelity_fabric(arrivals, dispatch, "event", n_nodes)
    fast = _run_fidelity_fabric(arrivals, dispatch, "fast", n_nodes)
    event_tl, event_shed, event_degrade = ev
    fast_tl, fast_shed, fast_degrade = fast
    assert event_tl, f"scenario {name} produced no responses"
    assert event_degrade, f"scenario {name} never stepped the ladder"
    assert fast_tl == event_tl
    assert fast_shed == event_shed
    assert fast_degrade == event_degrade


# --------------------------------------------------------------------- #
# multi-model: every registered mixed scenario
# --------------------------------------------------------------------- #
def _run_mm(name, engine):
    models = ("resnet50", "bert")
    units = UNITS
    share = units // len(models)
    contexts = {
        m: ScenarioContext(threads=share,
                           optimizer=PackratOptimizer(
                               PAPER_MODELS[m].profile(share, 32)),
                           duration=DURATION, seed=0)
        for m in models}
    mctx = MultiModelScenarioContext(models=models, contexts=contexts,
                                     duration=DURATION, seed=0)
    workloads = get_mm_scenario(name).build(mctx)
    traces = {m: workloads[m].arrivals(DURATION, seed=3 + k)
              for k, m in enumerate(models)}

    ccfg = ControllerConfig()
    ccfg.estimator.max_batch = 32
    specs = [TenantSpec(m, PAPER_MODELS[m].profile(units, 32),
                        TabulatedBackend(PAPER_MODELS[m].profile(units, 32)),
                        initial_batch=4)
             for m in models]
    loop = _loop(engine)
    server = MultiModelServer(loop, total_units=units, tenants=specs,
                              config=ccfg, adaptive=True, plan_interval=2.0)
    if engine == "fast":
        n_fed = feed_multi_model_trace(server, traces)
    else:
        merged = sorted((t, k, m) for k, m in enumerate(models)
                        for t in traces[m])
        for i, (t, _, m) in enumerate(merged):
            req = Request(i, t, model_id=m)
            loop.at(t, (lambda req=req: server.submit(req)))
    loop.run_until(DURATION + DRAIN)
    if engine == "fast":
        # the trace machinery must have accounted for every arrival
        fed = sum(server.tenants[m].dispatcher.fast_absorbed
                  + server.tenants[m].dispatcher.fast_one_by_one
                  for m in models)
        assert fed == n_fed == sum(len(tr) for tr in traces.values())
    return response_tuples(server.responses)


@pytest.mark.parametrize("name", MM_SCENARIO_NAMES)
def test_multimodel_differential(name):
    event_tl = _run_mm(name, "event")
    fast_tl = _run_mm(name, "fast")
    assert event_tl, f"mm scenario {name} produced no responses"
    assert fast_tl == event_tl


# --------------------------------------------------------------------- #
# pinned goldens through the FastPlane
# --------------------------------------------------------------------- #
def test_fast_plane_reproduces_golden_bulk_feed():
    """The PR 2 golden hash through the full fast stack: FastLoop trace
    absorption, columnar queue, flight execution, block delivery."""
    server, arrivals = golden_run("sync", FastLoop, fast_feed=True)
    assert isinstance(server.dispatcher, FastSyncDispatcher)
    assert isinstance(server.responses, ResponseLog)
    timeline = single_model_timeline(server)
    assert len(timeline) == len(arrivals) == 4789
    assert timeline_digest(timeline) == GOLDEN_SHA256
    # the bulk path actually engaged: multi-item blocks were delivered
    blocks = server.responses.blocks()
    assert any(isinstance(b, ResponseBlock) and len(b) > 1 for b in blocks)


def test_fast_plane_reproduces_golden_per_event_feed():
    """Same golden with per-arrival scheduling on the FastLoop (no trace
    machinery): the fast dispatcher alone must already be exact."""
    server, _ = golden_run("sync", FastLoop, fast_feed=False)
    assert timeline_digest(single_model_timeline(server)) == GOLDEN_SHA256


def test_fast_plane_continuous_matches_event_engine():
    """Continuous dispatch runs the vectorized continuous engine on the
    fast plane — bulk trace feed included — and stays exact."""
    event_server, _ = golden_run("continuous", EventLoop)
    fast_server, _ = golden_run("continuous", FastLoop, fast_feed=True)
    assert isinstance(fast_server.dispatcher, FastContinuousDispatcher)
    assert fast_server.dispatcher.fast_absorbed > 0
    assert (response_tuples(fast_server.responses)
            == response_tuples(event_server.responses))


@pytest.mark.parametrize("make_driver", [FastLoop,
                                         lambda: FastPlane(FastLoop())],
                         ids=["raw-fastloop", "explicit-fastplane"])
def test_fast_plane_reproduces_multimodel_golden(make_driver):
    timeline = mm_golden_run(make_driver())
    assert timeline_digest(timeline) == MM_GOLDEN_SHA256


# --------------------------------------------------------------------- #
# property: random traces, bulk feed vs event engine
# --------------------------------------------------------------------- #
def _check_fast_feed(seed, rate, fail_at, dispatch="sync"):
    arrivals = PoissonWorkload(rate_rps=rate).arrivals(5.0, seed=seed)

    def run(engine):
        loop = _loop(engine)
        server = PackratServer(
            loop, total_units=UNITS, optimizer=OPT8,
            backend=TabulatedBackend(PROFILE8), initial_batch=8,
            config=ControllerConfig(dispatch_policy=dispatch))
        if engine == "fast":
            feed_single_model_trace(server, arrivals)
        else:
            for i, t in enumerate(arrivals):
                loop.at(t, (lambda i=i, t=t:
                            server.submit(Request(i, t))))
        if fail_at is not None:
            loop.at(fail_at, lambda: server.inject_failure(0))
        loop.run_until(40.0)
        return response_tuples(server.responses)

    assert run("fast") == run("event")


@pytest.mark.parametrize("dispatch", DISPATCHES)
@pytest.mark.parametrize("seed,rate,fail_at",
                         [(0, 30.0, None), (1, 120.0, None),
                          (2, 200.0, 1.5), (3, 60.0, 0.5),
                          (4, 180.0, 3.9), (5, 25.0, 2.0)])
def test_fast_feed_matches_event_engine_seeded(seed, rate, fail_at, dispatch):
    _check_fast_feed(seed, rate, fail_at, dispatch)


def test_fast_feed_matches_event_engine_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 10_000),
           rate=st.floats(min_value=20.0, max_value=200.0),
           fail_at=st.one_of(st.none(), st.floats(0.5, 4.0)),
           dispatch=st.sampled_from(DISPATCHES))
    def check(seed, rate, fail_at, dispatch):
        _check_fast_feed(seed, rate, fail_at, dispatch)

    check()


# --------------------------------------------------------------------- #
# FastLoop merge-order semantics
# --------------------------------------------------------------------- #
def test_fastloop_trace_reserves_sequence_block():
    """Heap events scheduled before the trace win timestamp ties (lower
    seq); events scheduled after lose them — exactly as if every trace
    arrival had been pre-scheduled with at()."""
    loop = FastLoop()
    order = []
    loop.at(1.0, lambda: order.append("pre"))          # seq 0
    loop.add_trace([1.0, 2.0], lambda i, t: order.append(f"arr{i}"))
    loop.at(2.0, lambda: order.append("post"))         # seq after trace
    loop.run_until(3.0)
    assert order == ["pre", "arr0", "arr1", "post"]
    assert loop.now == 3.0


def test_fastloop_handler_scheduled_events_interleave():
    """An event scheduled by an arrival handler fires before later
    arrivals when its timestamp precedes them."""
    loop = FastLoop()
    order = []

    def arrive(i, t):
        order.append(("arr", i, loop.now))
        if i == 0:
            loop.at(t + 0.5, lambda: order.append(("timer", loop.now)))

    loop.add_trace([1.0, 2.0, 3.0], arrive)
    loop.run_until(10.0)
    assert order == [("arr", 0, 1.0), ("timer", 1.5),
                     ("arr", 1, 2.0), ("arr", 2, 3.0)]


def test_fastloop_run_drains_trace():
    loop = FastLoop()
    seen = []
    loop.add_trace([0.5, 1.5], lambda i, t: seen.append(t))
    loop.run()
    assert seen == [0.5, 1.5]
    assert loop.pending_arrivals == 0


def test_fastloop_absorber_consumes_in_bulk():
    loop = FastLoop()
    singles, absorbed = [], []

    def absorber(times, cur, bound):
        # absorb everything after the first arrival of each window
        k = bound - cur
        if k > 1 and times[cur] > 1.0:
            absorbed.extend(times[cur:bound].tolist())
            return k
        return 0

    loop.add_trace([1.0, 2.0, 2.5, 3.0], lambda i, t: singles.append(t),
                   absorber=absorber)
    loop.run_until(5.0)
    assert singles == [1.0]
    assert absorbed == [2.0, 2.5, 3.0]
    assert loop.now == 5.0


def test_fastloop_rejects_unsorted_and_overlapping_traces():
    loop = FastLoop()
    with pytest.raises(ValueError):
        loop.add_trace([2.0, 1.0], lambda i, t: None)
    loop.add_trace([1.0, 2.0], lambda i, t: None)
    with pytest.raises(ValueError):
        loop.add_trace([3.0], lambda i, t: None)


# --------------------------------------------------------------------- #
# ColumnQueue drop-in surface
# --------------------------------------------------------------------- #
def test_column_queue_deque_surface():
    q = ColumnQueue("m")
    assert len(q) == 0 and not q
    q.append(Request(1, 0.5, model_id="m"))
    q.append(Request(2, 0.75, model_id="m"))
    assert len(q) == 2 and q
    assert list(q) == [Request(1, 0.5, model_id="m"),
                       Request(2, 0.75, model_id="m")]
    assert q.popleft() == Request(1, 0.5, model_id="m")
    q.clear()
    assert len(q) == 0
    with pytest.raises(IndexError):
        q.popleft()


def test_column_queue_bulk_ops_and_growth():
    import numpy as np
    q = ColumnQueue()
    ids = np.arange(3000, dtype=np.int64)
    ts = np.linspace(0.0, 3.0, 3000)
    q.extend_arrays(ids, ts)                 # forces capacity growth
    assert len(q) == 3000
    got_ids, got_ts = q.pop_slice(5)
    assert got_ids.tolist() == [0, 1, 2, 3, 4]
    assert got_ts.tolist() == ts[:5].tolist()
    assert len(q) == 2995
    # popped slices are owned copies: later growth must not alias them
    q.extend_arrays(ids, ts)
    assert got_ids.tolist() == [0, 1, 2, 3, 4]


def test_response_log_materializes_blocks():
    import numpy as np
    log = ResponseLog()
    log.append_block(ResponseBlock(
        ids=np.array([7, 8], dtype=np.int64),
        arrivals=np.array([0.25, 0.5]), completion=1.0, batch_size=2,
        instance_id=3, redispatched=False, model_id="m"))
    assert len(log) == 2
    items = list(log)
    assert [r.request.id for r in items] == [7, 8]
    assert items[0].latency == 0.75 and items[1].latency == 0.5
    assert items[0].batch_size == 2 and items[0].instance_id == 3
    assert log[1].request.arrival == 0.5
