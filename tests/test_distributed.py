"""Distribution tests: sharding rules, compression, EP, HLO analysis.

These run on 8 fabricated host devices (set before jax import via the
conftest-free module-level guard) — small enough for CPU, structured the
same as the 256/512-chip production meshes.
"""

import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ShapeConfig, get_config
from repro.distributed.compression import (compressed_psum,
                                           dequantize_blockwise,
                                           psum_bytes_saved,
                                           quantize_blockwise)
from repro.distributed.sharding import (batch_pspecs, cache_pspecs,
                                        optimizer_pspecs, params_pspecs,
                                        to_named)
from repro.launch.hlo_analysis import collective_stats
from repro.launch.mesh import make_mesh, make_submesh
from repro.models import build_model

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 fabricated devices")


def small_mesh():
    return make_mesh((2, 4), ("data", "model"))


# --------------------------------------------------------------------- #
# sharding rules
# --------------------------------------------------------------------- #
def test_param_specs_divisibility():
    """Every spec must divide its dimension on the mesh (for all archs)."""
    mesh = small_mesh()
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for arch in ("llama3-8b", "deepseek-v2-236b", "recurrentgemma-9b",
                 "mamba2-130m", "seamless-m4t-medium", "gemma3-1b"):
        cfg = get_config(arch)
        model = build_model(cfg)
        p_shape = model.param_specs()
        specs = params_pspecs(cfg, p_shape, mesh)
        flat_l = jax.tree_util.tree_leaves(p_shape)
        flat_s = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_l) == len(flat_s)
        for leaf, spec in zip(flat_l, flat_s):
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                n = 1
                for a in axes:
                    n *= sizes[a]
                assert dim % n == 0, (arch, leaf.shape, spec)


def test_tensor_parallel_shards_big_matrices():
    """d_ff / attention heads actually shard over the model axis."""
    mesh = small_mesh()
    cfg = get_config("llama3-8b")
    model = build_model(cfg)
    specs = params_pspecs(cfg, model.param_specs(), mesh)
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    by_name = {jax.tree_util.keystr(k): v for k, v in flat}
    wq = next(v for k, v in by_name.items() if "wq" in k)
    assert "model" in jax.tree_util.tree_leaves(tuple(wq))
    up = next(v for k, v in by_name.items()
              if k.endswith("['up']") and "moe" not in k)
    assert "model" in jax.tree_util.tree_leaves(tuple(up))


def test_optimizer_zero_sharding_adds_data_axis():
    mesh = small_mesh()
    cfg = get_config("llama3-8b")
    model = build_model(cfg)
    p_shape = model.param_specs()
    p_spec = params_pspecs(cfg, p_shape, mesh)
    o_spec = optimizer_pspecs(p_spec, p_shape, mesh, zero=True)
    n_data = sum("data" in jax.tree_util.tree_leaves(tuple(s))
                 for s in jax.tree_util.tree_leaves(
                     o_spec, is_leaf=lambda x: isinstance(x, P)))
    n_data_params = sum("data" in jax.tree_util.tree_leaves(tuple(s))
                        for s in jax.tree_util.tree_leaves(
                            p_spec, is_leaf=lambda x: isinstance(x, P)))
    assert n_data > n_data_params     # moments got extra data sharding


def test_batch_specs_divisible_fallback():
    mesh = small_mesh()
    spec = batch_pspecs(jax.ShapeDtypeStruct((1, 7), jnp.int32), mesh)
    assert tuple(spec) == (None, None)   # B=1 cannot shard over data=2
    spec = batch_pspecs(jax.ShapeDtypeStruct((8, 7), jnp.int32), mesh)
    assert spec[0] in ("data", ("data",))


def test_sharded_train_step_executes():
    """Real execution on 8 devices: one sharded train step, loss finite."""
    from repro.data import batches_for_model
    from repro.training import AdamWConfig, TrainConfig, init_adamw, make_train_step

    mesh = small_mesh()
    cfg = get_config("llama3-8b").reduced(
        n_repeats=2, d_model=64, n_heads=4, d_ff=128, vocab_size=512)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tcfg = TrainConfig(adamw=AdamWConfig(warmup_steps=1))
    opt = init_adamw(tcfg.adamw, params)
    shape = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")
    batch = next(batches_for_model(cfg, shape))

    p_spec = params_pspecs(cfg, jax.eval_shape(lambda: params), mesh)
    with mesh:
        step = jax.jit(make_train_step(cfg, tcfg),
                       in_shardings=(to_named(mesh, p_spec), None, None))
        params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))


def test_sharded_decode_step_executes():
    """Real execution: decode with the seq-sharded cache layout."""
    mesh = small_mesh()
    cfg = get_config("llama3-8b").reduced(
        n_repeats=2, d_model=64, n_heads=4, d_ff=128, vocab_size=512)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(8, 64)
    c_spec = cache_pspecs(cfg, jax.eval_shape(lambda: cache), mesh)
    tokens = jnp.zeros((8, 1), jnp.int32)
    with mesh:
        step = jax.jit(
            lambda p, c, t, pos: model.decode_step(p, c, t, pos),
            in_shardings=(None, to_named(mesh, c_spec), None, None),
            out_shardings=(None, to_named(mesh, c_spec)))
        logits, cache2 = step(params, cache, tokens, jnp.int32(3))
    assert np.isfinite(np.asarray(logits, np.float32)).all()


# --------------------------------------------------------------------- #
# gradient compression
# --------------------------------------------------------------------- #
def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3.0
    q, s, pad = quantize_blockwise(x)
    back = dequantize_blockwise(q, s, pad, x.shape)
    err = np.abs(np.asarray(back - x))
    scale = np.abs(np.asarray(x)).max()
    assert err.max() <= scale / 127 + 1e-6


def test_compressed_psum_close_to_exact():
    mesh = make_mesh((8,), ("pod",))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 512))

    def f(xs):
        return compressed_psum(xs, "pod")

    from repro.distributed import shard_map
    got = jax.jit(shard_map(f, mesh=mesh, in_specs=P("pod"),
                            out_specs=P("pod")))(x)
    want = jnp.broadcast_to(x.sum(0, keepdims=True), x.shape)
    rms_rel = float(jnp.sqrt(jnp.mean((got - want) ** 2))
                    / jnp.sqrt(jnp.mean(want ** 2)))
    assert rms_rel < 0.02


def test_compression_saves_bytes():
    tree = {"w": jnp.zeros((1 << 20,))}
    full, comp = psum_bytes_saved(tree)
    assert comp < full / 3.5


# --------------------------------------------------------------------- #
# expert parallel path vs dense-dispatch oracle
# --------------------------------------------------------------------- #
def test_moe_ep_matches_dense_dispatch():
    from repro.distributed.expert_parallel import apply_moe_ep
    from repro.models.moe import apply_moe, init_moe

    mesh = make_mesh((8,), ("model",))
    cfg = get_config("deepseek-v2-236b").reduced(
        n_repeats=1, d_model=32, n_heads=4, d_ff=64)
    # 8 experts over 8 shards; uncapped-ish capacity for exactness
    import dataclasses
    cfg = cfg.with_overrides(moe=dataclasses.replace(
        cfg.moe, n_experts=8, top_k=2, capacity_factor=8.0))
    params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    want = apply_moe(params, x, cfg)
    with mesh:
        got = jax.jit(lambda p, xx: apply_moe_ep(p, xx, cfg, mesh=mesh))(
            params, x)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=2e-4, rtol=2e-3)


# --------------------------------------------------------------------- #
# HLO collective parsing
# --------------------------------------------------------------------- #
def test_collective_stats_parser():
    hlo = """
  %all-reduce = f32[128,256]{1,0} all-reduce(%x), replica_groups={}
  %ag = (bf16[64]{0}, bf16[32]{0}) all-gather(%a, %b), dim=0
  %rs = f32[16,16]{1,0} reduce-scatter(%y), dimensions={0}
  %cp-start = bf16[8]{0} collective-permute-start(%z)
  %cp-done = bf16[8]{0} collective-permute-done(%cp-start)
  %fusion = f32[4]{0} fusion(%w), calls=%comp
"""
    stats = collective_stats(hlo)
    assert stats.count_by_op["all-reduce"] == 1
    assert stats.bytes_by_op["all-reduce"] == 128 * 256 * 4
    assert stats.bytes_by_op["all-gather"] == (64 + 32) * 2
    assert stats.bytes_by_op["reduce-scatter"] == 16 * 16 * 4
    assert stats.count_by_op["collective-permute"] == 1  # start+done once
    assert stats.total_count == 4


def test_collective_stats_on_real_program():
    mesh = small_mesh()
    from jax.sharding import NamedSharding

    def f(w, x):
        return (x @ w).sum()

    w = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    with mesh:
        comp = jax.jit(
            f, in_shardings=(NamedSharding(mesh, P(None, "model")),
                             NamedSharding(mesh, P("data", None))),
            out_shardings=NamedSharding(mesh, P())).lower(w, x).compile()
    stats = collective_stats(comp.as_text())
    assert stats.count_by_op.get("all-reduce", 0) >= 1


def test_submesh_shapes():
    m = make_submesh(8)
    assert m.devices.size == 8 and m.shape["model"] == 8
    m = make_submesh(8, model_parallel=4)
    assert m.shape["data"] == 2 and m.shape["model"] == 4
    with pytest.raises(ValueError):
        make_submesh(8, model_parallel=3)
