"""Workload scenario engine tests: seeded determinism, rate fidelity,
trace round-trips (ISSUE 1 tentpole coverage), and pinned cross-version
arrival digests."""

import hashlib
import math

import numpy as np
import pytest

from repro.serving.workloads import (DiurnalWorkload, MMPPWorkload,
                                     PoissonWorkload, RampWorkload,
                                     StepWorkload, TraceWorkload, Workload)

ALL_GENERATORS = [
    PoissonWorkload(rate_rps=40.0),
    StepWorkload(low=10.0, high=60.0, t_step=10.0),
    RampWorkload(start_rps=5.0, end_rps=50.0, t0=0.0, t1=20.0),
    DiurnalWorkload(base_rps=30.0, amplitude=0.6, period=20.0),
    MMPPWorkload(rates=(5.0, 50.0), mean_dwell=(4.0, 2.0)),
]


# --------------------------------------------------------------------- #
# determinism + basic well-formedness
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("wl", ALL_GENERATORS, ids=lambda w: w.name)
def test_seeded_determinism(wl):
    a = wl.arrivals(20.0, seed=7)
    b = wl.arrivals(20.0, seed=7)
    assert a == b, "same seed must give identical arrivals"
    c = wl.arrivals(20.0, seed=8)
    assert a != c, "different seeds must give different sample paths"


# sha256 of the first 256 arrivals (float64 buffer) of each generator at
# seed 2026 over 60 s.  These pin the *exact sample path* across
# refactors: the fast simulation core replays pre-generated traces, so
# any silent change to a generator's RNG stream would shift every
# downstream golden.  If a generator's algorithm changes intentionally,
# re-capture with the snippet in the test body.
ARRIVAL_DIGESTS = {
    "poisson": ("b40657fd6f6d9f4aeea507bf7e34895d"
                "1eddc705cf3a1bb38f93c571dc0bb6c4"),
    "step": ("da7570a8a72aed9e18b6aac1e0ead319"
             "6aca478f120b3414f72304d56e7810e3"),
    "ramp": ("30e9ceb2076de3c4a068f834ee527b2b"
             "be36dec9cf3030f7fd9c6c2cc4bb8a22"),
    "diurnal": ("a942fb9e27a3e4924a6aebebdc58bf07"
                "b39c7c2224d04c7fbb04e5365f6124a6"),
    "mmpp": ("2762433e4e209e2f737a804645b61f47"
             "2a932af90ecbd03a37aa5726e5df50cc"),
}


@pytest.mark.parametrize("wl", ALL_GENERATORS, ids=lambda w: w.name)
def test_pinned_arrival_digest(wl):
    times = wl.arrivals(60.0, seed=2026)
    assert len(times) >= 256, "digest window must be fully populated"
    head = np.asarray(times[:256], dtype=np.float64)
    digest = hashlib.sha256(head.tobytes()).hexdigest()
    assert digest == ARRIVAL_DIGESTS[wl.name], (
        f"{wl.name} sample path drifted — this breaks trace replay "
        f"reproducibility; only re-pin on an intentional generator change")


@pytest.mark.parametrize("wl", ALL_GENERATORS, ids=lambda w: w.name)
def test_arrivals_sorted_and_bounded(wl):
    times = wl.arrivals(20.0, seed=0)
    assert times == sorted(times)
    assert all(0.0 <= t < 20.0 for t in times)


# --------------------------------------------------------------------- #
# empirical rate vs configured rate
# --------------------------------------------------------------------- #
def test_poisson_empirical_rate():
    wl = PoissonWorkload(rate_rps=50.0)
    duration = 80.0
    n = len(wl.arrivals(duration, seed=3))
    # Poisson(50*80=4000): 4 sigma ≈ 253, so ±10% is a safe bound
    assert abs(n / duration - 50.0) / 50.0 < 0.10


def test_step_rates_before_and_after():
    wl = StepWorkload(low=10.0, high=80.0, t_step=30.0)
    assert wl.rate(0.0) == 10.0 and wl.rate(29.999) == 10.0
    assert wl.rate(30.0) == 80.0
    times = wl.arrivals(60.0, seed=1)
    before = sum(1 for t in times if t < 30.0) / 30.0
    after = sum(1 for t in times if t >= 30.0) / 30.0
    assert abs(before - 10.0) / 10.0 < 0.35
    assert abs(after - 80.0) / 80.0 < 0.15


def test_ramp_rate_function():
    wl = RampWorkload(start_rps=10.0, end_rps=50.0, t0=5.0, t1=15.0)
    assert wl.rate(0.0) == 10.0
    assert wl.rate(10.0) == pytest.approx(30.0)
    assert wl.rate(20.0) == 50.0
    assert wl.max_rate(20.0) == 50.0


def test_diurnal_rate_curve_and_mean():
    wl = DiurnalWorkload(base_rps=40.0, amplitude=0.5, period=40.0)
    assert wl.rate(10.0) == pytest.approx(60.0)   # peak: base*(1+amp)
    assert wl.rate(30.0) == pytest.approx(20.0)   # trough: base*(1-amp)
    assert wl.max_rate(40.0) == pytest.approx(60.0)
    # sin integrates to ~0 over whole periods → mean ≈ base
    assert wl.mean_rate(40.0) == pytest.approx(40.0, rel=0.02)
    n = len(wl.arrivals(80.0, seed=5))            # two full periods
    assert abs(n / 80.0 - 40.0) / 40.0 < 0.12


def test_diurnal_rejects_bad_amplitude():
    with pytest.raises(ValueError):
        DiurnalWorkload(base_rps=10.0, amplitude=1.5)


def test_mmpp_stationary_rate_and_burstiness():
    wl = MMPPWorkload(rates=(5.0, 50.0), mean_dwell=(6.0, 3.0))
    stat = wl.stationary_rate()
    assert stat == pytest.approx((6 * 5 + 3 * 50) / 9.0)
    assert wl.rate(12.3) == stat
    # long-run empirical rate converges to the stationary rate
    duration = 400.0
    n = len(wl.arrivals(duration, seed=2))
    assert abs(n / duration - stat) / stat < 0.25
    # burstiness: a Poisson process of equal mean rate has exponential
    # gaps with CV=1; MMPP gaps must be over-dispersed (CV > 1)
    times = wl.arrivals(duration, seed=2)
    gaps = [b - a for a, b in zip(times, times[1:])]
    mean = sum(gaps) / len(gaps)
    var = sum((g - mean) ** 2 for g in gaps) / len(gaps)
    assert math.sqrt(var) / mean > 1.1


def test_mmpp_arrivals_align_with_state_path():
    # the published state path must describe the arrivals of the same
    # seed: during a (strictly positive-length) zero-rate dwell there
    # are no arrivals at all
    wl = MMPPWorkload(rates=(0.0, 80.0), mean_dwell=(5.0, 5.0))
    duration, seed = 200.0, 6
    path = wl.state_path(duration, seed=seed)
    times = wl.arrivals(duration, seed=seed)
    bounds = [t for t, _ in path[1:]] + [duration]
    assert times, "high-rate dwells must produce arrivals"
    for (t0, k), t1 in zip(path, bounds):
        n = sum(1 for t in times if t0 <= t < t1)
        if wl.rates[k] == 0.0:
            assert n == 0, f"arrival inside zero-rate dwell [{t0},{t1})"


def test_mmpp_state_path_seeded():
    wl = MMPPWorkload(rates=(1.0, 10.0), mean_dwell=(5.0, 5.0))
    p1 = wl.state_path(100.0, seed=4)
    assert p1 == wl.state_path(100.0, seed=4)
    assert p1[0] == (0.0, 0)
    states = [k for _, k in p1]
    assert states == [i % 2 for i in range(len(states))]  # cyclic chain


def test_mmpp_rejects_mismatched_states():
    with pytest.raises(ValueError):
        MMPPWorkload(rates=(1.0, 2.0, 3.0), mean_dwell=(1.0, 2.0))


# --------------------------------------------------------------------- #
# trace replay
# --------------------------------------------------------------------- #
def test_trace_round_trip_json(tmp_path):
    src = PoissonWorkload(rate_rps=30.0)
    trace = TraceWorkload.record(src, 10.0, seed=9)
    path = tmp_path / "trace.json"
    trace.save_json(path)
    loaded = TraceWorkload.from_json(path)
    assert loaded.times == trace.times
    assert TraceWorkload.from_file(path).times == trace.times


def test_trace_round_trip_csv(tmp_path):
    trace = TraceWorkload(times=(0.125, 1.5, 2.75, 9.0625))
    path = tmp_path / "trace.csv"
    trace.save_csv(path)
    loaded = TraceWorkload.from_csv(path)
    assert loaded.times == trace.times          # repr() round-trips floats
    assert TraceWorkload.from_file(path).times == trace.times


def test_trace_replay_ignores_seed_and_clips():
    trace = TraceWorkload(times=(1.0, 2.0, 3.0, 14.0))
    assert trace.arrivals(10.0, seed=0) == trace.arrivals(10.0, seed=99)
    assert trace.arrivals(10.0) == [1.0, 2.0, 3.0]
    assert trace.mean_rate(10.0) == pytest.approx(0.3)


def test_trace_rejects_unsorted():
    with pytest.raises(ValueError):
        TraceWorkload(times=(2.0, 1.0))
    with pytest.raises(ValueError):
        TraceWorkload(times=(-1.0, 1.0))


def test_trace_empirical_rate_window():
    trace = TraceWorkload(times=(1.0, 1.1, 1.2, 5.0))
    assert trace.rate(1.1, window=1.0) == pytest.approx(3.0)
    assert trace.rate(5.0) == pytest.approx(1.0)


def test_record_freezes_any_workload():
    wl = StepWorkload(low=5.0, high=40.0, t_step=5.0)
    trace = TraceWorkload.record(wl, 10.0, seed=3)
    assert list(trace.times) == wl.arrivals(10.0, seed=3)


def test_base_workload_abstract():
    with pytest.raises(NotImplementedError):
        Workload().rate(0.0)
