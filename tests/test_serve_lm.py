"""Autoregressive LM serving path (PR 9).

* **KV-cache differential** — prefill-then-N-decode-steps through the
  LmEngine's jitted (donating) callables equals the full-sequence
  forward, parametrized over seq buckets and batch pow2 cells, plus a
  ring-cache case that decodes past the sliding window.
* **Engine cells** — pow2 bucketing of runner cells, the resident
  decode pool's position wrap, and the phase-aware plane factory.
* **Plane integration** — phase-keyed runner cache, LRU eviction
  accounting, compile-ahead warm-up, and the dispatcher's decode-step
  continuation hook (a completed step re-enqueues until exhaustion).
"""

import collections
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.knapsack import (InstanceGroup, PackratConfig,
                                 next_power_of_two)
from repro.core.profiler import ProfileSpec, phase_profiles
from repro.models.lm import apply_head
from repro.models.serve_lm import (LM_MODELS, LmEngine, PHASE_DECODE,
                                   PHASE_PREFILL, PHASES, lm_tiny_config,
                                   make_lm_engine)
from repro.serving import (EventLoop, RealPlane, Request, SimulatedPlane,
                           TabulatedBackend, WorkerInstance, make_policy)
from repro.serving.dispatcher import Dispatcher, DispatcherConfig

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="module")
def engine():
    # max_seq 96 > the reduced gemma3 sliding window so the ring-cache
    # decode path is reachable from the differential test
    return LmEngine(max_seq=96)


# --------------------------------------------------------------------- #
# KV-cache differential: prefill + N decode steps == full forward
# --------------------------------------------------------------------- #
def _full_logits(engine, tokens):
    h = engine.model.forward(engine.params, {"tokens": tokens})
    return apply_head(engine.params, h, engine.cfg)


def _prefill_then_decode(engine, tokens, n_pre):
    """Max relative error of the incremental path vs the full forward."""
    tokens = jnp.asarray(tokens, jnp.int32)
    S = tokens.shape[1]
    full = _full_logits(engine, tokens)
    scale = float(jnp.max(jnp.abs(full))) + 1e-6
    logits_last, cache = engine.prefill(tokens[:, :n_pre])
    errs = [float(jnp.max(jnp.abs(logits_last[:, 0] - full[:, n_pre - 1])))]
    for i in range(n_pre, S):
        logits, cache = engine.decode_step(cache, tokens[:, i:i + 1],
                                           jnp.int32(i))
        errs.append(float(jnp.max(jnp.abs(logits[:, 0] - full[:, i]))))
    return max(errs) / scale


@pytest.mark.parametrize("b,n_pre", [
    (1, 8), (2, 8),             # smallest seq bucket
    (1, 16), (4, 16),           # default serving bucket
    (2, 32),                    # largest pow2 bucket below the window
])
def test_prefill_decode_matches_full_forward(engine, b, n_pre):
    n_dec = 4
    tokens = jax.random.randint(jax.random.PRNGKey(b * 100 + n_pre),
                                (b, n_pre + n_dec), 0,
                                engine.cfg.vocab_size)
    assert _prefill_then_decode(engine, tokens, n_pre) < 2e-4


def test_decode_past_sliding_window_stays_faithful(engine):
    """The ring cache keeps decode exact once positions wrap the window."""
    window = engine.cfg.sliding_window
    assert window and window < engine.max_seq
    n_pre, S = window + 8, window + 16       # steps cross the wrap point
    tokens = jax.random.randint(jax.random.PRNGKey(7), (1, S), 0,
                                engine.cfg.vocab_size)
    assert _prefill_then_decode(engine, tokens, n_pre) < 2e-4


def test_lm_tiny_config_serves_through_pallas():
    cfg = lm_tiny_config()
    assert cfg.use_pallas_kernels
    assert cfg.name == "lm-tiny"
    no_kernels = cfg.with_overrides(use_pallas_kernels=False)
    with pytest.raises(ValueError, match="use_pallas_kernels"):
        LmEngine(no_kernels)


# --------------------------------------------------------------------- #
# runner cells: pow2 bucketing, resident pool, phase-aware factory
# --------------------------------------------------------------------- #
def test_prefill_runner_cells_bucket_pow2(engine):
    assert engine.prefill_runner(1, 3) is engine.prefill_runner(2, 4)
    assert engine.prefill_runner(1, 4) is not engine.prefill_runner(1, 8)
    # seq buckets key distinct cells too
    assert engine.prefill_runner(1, 4, 8) is not engine.prefill_runner(1, 4, 16)


def test_decode_runner_pool_advances_and_wraps(engine):
    run = engine.decode_runner(1, 2)
    s0 = engine.default_seq_bucket
    _, pos0 = engine._resident[2]
    for _ in range(2 * (engine.max_seq - s0)):
        run()
        _, pos = engine._resident[2]
        assert s0 <= pos < engine.max_seq
    assert engine.decode_runner(4, 2) is run      # t does not key the cell


def test_factory_routes_phases(engine):
    make = engine.factory()
    assert getattr(make, "phase_aware", False)
    assert make(1, 2, PHASE_PREFILL) is engine.prefill_runner(1, 2)
    assert make(1, 2, PHASE_DECODE) is engine.decode_runner(1, 2)
    assert make(1, 2) is engine.decode_runner(1, 2)   # default phase


def test_make_lm_engine_registry():
    assert "lm-tiny" in LM_MODELS
    assert PHASES == (PHASE_PREFILL, PHASE_DECODE)
    with pytest.raises(ValueError, match="unknown LM serving model"):
        make_lm_engine("no-such-model")


# --------------------------------------------------------------------- #
# RealPlane: phase-keyed runner cache, LRU bound, warm-up
# --------------------------------------------------------------------- #
def _phase_factory(calls):
    def make(t, b, phase=""):
        def run():
            calls[(phase, t, b)] += 1
            time.sleep(0.0002)
        return run
    make.phase_aware = True
    return make


def test_plane_runner_cache_is_phase_keyed():
    calls = collections.Counter()
    plane = RealPlane(_phase_factory(calls), total_units=2)
    a = plane.runner(1, 2, phase="prefill")
    b = plane.runner(1, 2, phase="decode")
    assert a is not b
    # partial batches round up into the pow2 cell
    c = plane.runner(1, 3, phase="prefill")
    assert c is plane.runner(1, 4, phase="prefill") and c is not a
    rep = plane.runner_report()
    assert rep["cached"] == 3 and rep["evictions"] == 0
    assert set(rep["compile_ms"]) == {"prefill:1,2", "decode:1,2",
                                      "prefill:1,4"}
    plane.close()


def test_plane_runner_lru_bound_evicts_and_counts():
    calls = collections.Counter()
    plane = RealPlane(_phase_factory(calls), total_units=2, max_runners=2)
    plane.runner(1, 1, phase="decode")
    plane.runner(1, 2, phase="decode")
    plane.runner(1, 4, phase="decode")      # evicts the (1,1) cell
    assert plane.runner_evictions == 1
    rep = plane.runner_report()
    assert rep["cached"] == 2 and rep["evictions"] == 1
    # compile_ms history survives eviction (it is an accounting record,
    # excluded from latency percentiles, not a cache)
    assert "decode:1,1" in rep["compile_ms"]
    plane.close()


def test_plane_warm_compiles_ahead_of_traffic():
    calls = collections.Counter()
    plane = RealPlane(_phase_factory(calls), total_units=2)
    warmed = plane.warm([(1, 2), (2, 4)], phase="prefill")
    assert warmed == 2
    assert plane.runner_report()["cached"] == 2
    # warm again: cells already resident, nothing new compiles
    assert plane.warm([(1, 2)], phase="prefill") == 0
    plane.close()


def test_phase_profiles_measures_each_phase():
    calls = collections.Counter()
    plane = RealPlane(_phase_factory(calls), total_units=2)
    spec = ProfileSpec(2, 2, thread_values=(1, 2))
    profs = phase_profiles(plane, spec, ("prefill", "decode"),
                           warmup=1, iters=2)
    assert set(profs) == {"prefill", "decode"}
    for phase in profs:
        assert set(profs[phase]) == set(spec.grid())
        assert all(lat > 0 for lat in profs[phase].values())
    assert calls[("prefill", 1, 1)] == 3 and calls[("decode", 1, 1)] == 3
    plane.close()


# --------------------------------------------------------------------- #
# dispatcher continuation: completed steps re-enqueue until exhaustion
# --------------------------------------------------------------------- #
def test_dispatcher_continuation_chains_decode_steps():
    profile = {(1, b): 0.010 for b in (1, 2, 4)}
    config = PackratConfig(groups=(InstanceGroup(1, 1, 2),),
                           latency=profile[(1, 2)])
    plane = SimulatedPlane(EventLoop())
    workers = [WorkerInstance(0, 1, 2, TabulatedBackend(profile))]
    responses = []
    disp = Dispatcher(plane, config, workers, responses.append,
                      DispatcherConfig(batch_timeout=0.005),
                      policy=make_policy("continuous"))

    def continue_chain(resp):
        if resp.request.steps_left > 1:
            return Request(resp.request.id + 1000,
                           plane.now,
                           phase=PHASE_DECODE,
                           steps_left=resp.request.steps_left - 1)
        return None

    disp.continuation = continue_chain
    n, steps = 3, 4
    for i in range(n):
        plane.at(0.001 * (i + 1), (lambda i=i: disp.on_request(
            Request(i, 0.001 * (i + 1), phase=PHASE_DECODE,
                    steps_left=steps))))
    plane.run_until(5.0)
    # each root request spawns steps-1 continuations
    assert len(responses) == n * steps
    chains = collections.Counter(r.request.id % 1000 for r in responses)
    assert all(v == steps for v in chains.values())


def test_dispatcher_without_continuation_is_unchanged():
    profile = {(1, b): 0.010 for b in (1, 2, 4)}
    config = PackratConfig(groups=(InstanceGroup(1, 1, 2),),
                           latency=profile[(1, 2)])
    plane = SimulatedPlane(EventLoop())
    workers = [WorkerInstance(0, 1, 2, TabulatedBackend(profile))]
    responses = []
    disp = Dispatcher(plane, config, workers, responses.append,
                      DispatcherConfig(batch_timeout=0.005),
                      policy=make_policy("continuous"))
    assert disp.continuation is None
    for i in range(4):
        plane.at(0.001 * (i + 1), (lambda i=i: disp.on_request(
            Request(i, 0.001 * (i + 1)))))
    plane.run_until(5.0)
    assert sorted(r.request.id for r in responses) == list(range(4))


# --------------------------------------------------------------------- #
# end-to-end: the LM factory behind a real plane
# --------------------------------------------------------------------- #
def test_lm_factory_serves_through_real_plane(engine):
    plane = RealPlane(engine.factory(), total_units=2)
    profile = plane.profile(ProfileSpec(2, 2, thread_values=(1, 2)),
                            warmup=0, iters=1, phase=PHASE_DECODE)
    assert all(lat > 0 for lat in profile.values())
    rep = plane.runner_report()
    assert rep["cached"] >= 1
    plane.close()


def test_request_carries_phase_fields():
    r = Request(1, 0.0, phase=PHASE_PREFILL, seq_bucket=16, steps_left=8)
    assert r.phase == PHASE_PREFILL
    assert r.seq_bucket == 16 and r.steps_left == 8
    assert Request(2, 0.0).phase == ""        # phaseless default intact


# --------------------------------------------------------------------- #
# phase-split planning: prefill and decode solved as separate cells
# (placed here rather than test_knapsack.py: that module is skipped
# wholesale when hypothesis is unavailable)
# --------------------------------------------------------------------- #
def test_phase_split_minimizes_joint_makespan():
    from repro.core import PackratOptimizer
    from repro.core.knapsack import solve_phase_split
    # prefill is 3x the cost of decode at every cell: the split must give
    # prefill the lion's share of the units
    prefill = {(t, b): 3.0 * b / t for t in (1, 2, 4) for b in (1, 2, 4)}
    decode = {(t, b): 1.0 * b / t for t in (1, 2, 4) for b in (1, 2, 4)}
    opts = {"prefill": PackratOptimizer(prefill),
            "decode": PackratOptimizer(decode)}
    split = solve_phase_split(opts, {"prefill": 4, "decode": 4}, 8)
    assert split is not None
    assert sum(split["units"].values()) == 8
    assert all(u >= 1 for u in split["units"].values())
    assert split["objective"] == pytest.approx(
        max(c.latency for c in split["configs"].values()))
    # min-max optimal: no other feasible unit partition does better
    feasible = []
    for u_pre in range(1, 8):
        c_pre = opts["prefill"].try_solve(u_pre, 4)
        c_dec = opts["decode"].try_solve(8 - u_pre, 4)
        if c_pre and c_dec:
            feasible.append(max(c_pre.latency, c_dec.latency))
    assert feasible
    assert split["objective"] == pytest.approx(min(feasible))
    # prefill is 3x slower per cell, so it can never get fewer units
    assert split["units"]["prefill"] >= split["units"]["decode"]


def test_phase_split_infeasible_returns_none():
    from repro.core import PackratOptimizer
    from repro.core.knapsack import solve_phase_split
    profile = {(2, 2): 1.0}
    opts = {"prefill": PackratOptimizer(profile),
            "decode": PackratOptimizer(profile)}
    # one unit cannot host two phase pools
    assert solve_phase_split(opts, {"prefill": 2, "decode": 2}, 1) is None
    # 3 units: one side gets 1 unit but the only item needs t=2
    assert solve_phase_split(opts, {"prefill": 2, "decode": 2}, 3) is None
    assert solve_phase_split(opts, {"prefill": 2, "decode": 2}, 4) \
        is not None


def test_phase_split_validates_inputs():
    from repro.core import PackratOptimizer
    from repro.core.knapsack import solve_phase_split
    opt = PackratOptimizer({(1, 1): 1.0})
    with pytest.raises(ValueError):
        solve_phase_split({"prefill": opt}, {"prefill": 1}, 4)
    with pytest.raises(ValueError):
        solve_phase_split({"a": opt, "b": opt}, {"a": 1, "c": 1}, 4)
    with pytest.raises(ValueError):
        solve_phase_split({"a": opt, "b": opt}, {"a": 1, "b": 1}, 4,
                          min_units=0)


# --------------------------------------------------------------------- #
# per-phase batch estimation (test_estimator.py is hypothesis-gated)
# --------------------------------------------------------------------- #
def test_phase_estimator_tracks_phases_independently():
    from repro.core.estimator import EstimatorConfig, PhaseEstimator
    est = PhaseEstimator(config=EstimatorConfig(alpha=0.5, window=4,
                                                reconfigure_timeout=0.0),
                         initial_batch=4)
    for _ in range(30):
        est.observe("prefill", 4)      # steady
        est.observe("decode", 32)      # 8x the prefill demand
    assert est.smoothed_batches() == {"prefill": 4, "decode": 32}
    changed = est.should_reconfigure(now=1.0)
    assert changed == {"decode": 32}   # only decode drifted from B=4
    est.commit(changed)
    assert est.current_batches() == {"prefill": 4, "decode": 32}
    # committed: the next check is quiet
    assert est.should_reconfigure(now=2.0) is None


def test_phase_estimator_validates_phases():
    from repro.core.estimator import PhaseEstimator
    with pytest.raises(ValueError):
        PhaseEstimator(phases=())
    est = PhaseEstimator()
    with pytest.raises(KeyError):
        est.observe("no-such-phase", 1)
