"""Elastic-fidelity overload control: the degrade-ladder verification
harness (ISSUE 10).

Three families of guarantees:

* **ladder-before-shed** — on hypothesis-generated overload traces, no
  request is ever queue-shed while a lower fidelity rung was still
  feasible on the shedding node (replayed from the router's degrade
  log: every queue shed finds its node fully degraded at the bottom
  rung);
* **reverse-order recovery** — after overload clears, a node releases
  the batch floor first and then climbs the fidelity rungs one at a
  time under the hysteresis gate, never skipping a rung and never
  climbing while still degraded;
* **fidelity-off byte-identity** — with no ladder configured, the
  pinned golden timelines reproduce exactly (both golden shas) and the
  fabric report carries none of the fidelity keys.
"""

import pytest

from repro.core import FidelityLadder, HysteresisGate, PackratOptimizer
from repro.core.knapsack import FidelityRung
from repro.core.paper_profiles import RESNET50, fidelity_ladder
from repro.serving import (ClusterRouter, EventLoop, FabricConfig,
                           FabricNodeSpec, Request, TabulatedBackend)

from oracles import (GOLDEN_SHA256, MM_GOLDEN_SHA256, golden_run,
                     mm_golden_run, single_model_timeline, timeline_digest)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

UNITS = 8
MAX_BATCH = 64
PROFILE = RESNET50.profile(UNITS, MAX_BATCH)
N_RUNGS = 3
BOTTOM = N_RUNGS - 1


def node_capacity() -> float:
    return PackratOptimizer(PROFILE).solve(UNITS, MAX_BATCH).throughput


def make_router(loop, n_nodes=3, *, ladder=True, slo=1.0, seed=0,
                config=None):
    specs = [FabricNodeSpec(
        optimizer=PackratOptimizer(PROFILE),
        backend=TabulatedBackend(PROFILE),
        ladder=(fidelity_ladder(RESNET50, UNITS, MAX_BATCH)
                if ladder else None))
        for _ in range(n_nodes)]
    cfg = config or FabricConfig(p2c_seed=seed)
    return ClusterRouter(loop, units_per_node=UNITS, specs=specs,
                         initial_batch=4, slo_deadline=slo, config=cfg)


def offer_segments(loop, router, segments, *, start=0.0):
    """Deterministic evenly spaced arrivals: ``segments`` is a list of
    (rate_rps, seconds); returns (n_offered, end_time)."""
    t, i = start, 0
    for rate, seconds in segments:
        n = int(rate * seconds)
        for k in range(n):
            at = t + (k + 0.5) / rate
            loop.at(at, (lambda i=i, at=at: router.submit(Request(i, at))))
            i += 1
        t += seconds
    return i, t


def replay_ladder_states(router):
    """Replay the degrade log into per-node (time-ordered) state
    snapshots: a list of (t, rung, degraded) transitions per node."""
    states = {n.node_id: [(float("-inf"), 0, False)] for n in router.nodes}
    for t, nid, event in router.degrade_log:
        _, rung, degraded = states[nid][-1]
        if event == "enter":
            degraded = True
        elif event == "exit":
            degraded = False
        elif event.startswith("rung"):
            rung = int(event[4:])
        else:                                          # pragma: no cover
            raise AssertionError(f"unknown degrade event {event!r}")
        states[nid].append((t, rung, degraded))
    return states


def state_at(snapshots, t):
    """Node state (rung, degraded) after all events at time <= t — the
    degrade step inside submit() logs before the shed decision, so
    same-timestamp events are included."""
    rung, degraded = 0, False
    for et, r, d in snapshots:
        if et <= t:
            rung, degraded = r, d
        else:
            break
    return rung, degraded


def assert_ladder_invariants(router):
    """The harness's core checks, valid for any trace:

    * a "queue" shed only happens on a node that is degraded at the
      bottom rung (no shed while a lower rung was feasible);
    * rungs move one step at a time, in either direction;
    * the batch floor only engages at the bottom rung;
    * rung-up (recovery) steps only happen after the floor is released
      (reverse order) and in strictly decreasing rung order.
    """
    states = replay_ladder_states(router)
    for shed in router.sheds:
        if shed.reason != "queue":
            continue
        rung, degraded = state_at(states[shed.node_id], shed.time)
        assert degraded and rung == BOTTOM, (
            f"request {shed.request.id} queue-shed on {shed.node_id} at "
            f"t={shed.time:.3f} with rung={rung} degraded={degraded} — "
            f"a lower fidelity rung was still feasible")
    for nid, snapshots in states.items():
        prev_rung, prev_deg = 0, False
        for t, rung, degraded in snapshots[1:]:
            if degraded and not prev_deg:
                assert rung == BOTTOM, (
                    f"{nid}: batch floor engaged at rung {rung} with "
                    f"rungs below it unused")
            if rung != prev_rung:
                assert abs(rung - prev_rung) == 1, (
                    f"{nid}: rung jumped {prev_rung} -> {rung}")
                if rung < prev_rung:
                    assert not prev_deg and not degraded, (
                        f"{nid}: climbed to rung {rung} while the batch "
                        f"floor was still engaged")
            prev_rung, prev_deg = rung, degraded


def assert_exactly_once(router):
    ids = [r.request.id for r in router.responses]
    assert len(ids) == len(set(ids)), "duplicate delivery"
    shed_ids = {s.request.id for s in router.sheds}
    assert not (shed_ids & set(ids)), "shed request also delivered"


# --------------------------------------------------------------------- #
# ladder / gate primitives
# --------------------------------------------------------------------- #
def test_fidelity_ladder_validation():
    rungs = fidelity_ladder(RESNET50, UNITS, MAX_BATCH).rungs
    # rung 0 must be full quality
    with pytest.raises(ValueError):
        FidelityLadder([FidelityRung(0, "a", 0.9, rungs[0].profile)])
    # qualities must be nonincreasing top-down
    with pytest.raises(ValueError):
        FidelityLadder([
            rungs[0],
            FidelityRung(1, "b", 0.5, rungs[1].profile),
            FidelityRung(2, "c", 0.9, rungs[2].profile)])
    # rung indices must be 0..n-1 in order
    with pytest.raises(ValueError):
        FidelityLadder([rungs[0], rungs[2]])


def test_hysteresis_gate_requires_consecutive_calm():
    with pytest.raises(ValueError):
        HysteresisGate(required=0)
    gate = HysteresisGate(required=3)
    # a hot observation mid-streak resets the count
    assert [gate.observe(c) for c in (True, True, False, True, True)] == \
        [False] * 5
    assert gate.resets == 1
    # the third *consecutive* calm observation opens the gate...
    assert gate.observe(True) is True
    assert gate.opens == 1
    # ...and the streak restarts from zero afterwards
    assert [gate.observe(True) for _ in range(2)] == [False, False]
    assert gate.observe(True) is True
    assert gate.opens == 2


def test_router_rejects_ladder_whose_top_rung_differs():
    ladder = fidelity_ladder(RESNET50, UNITS, 32)   # grid != optimizer's
    spec = FabricNodeSpec(optimizer=PackratOptimizer(PROFILE),
                          backend=TabulatedBackend(PROFILE), ladder=ladder)
    with pytest.raises(ValueError, match="rung 0"):
        ClusterRouter(EventLoop(), units_per_node=UNITS, specs=[spec],
                      initial_batch=4, slo_deadline=1.0)


def test_solve_with_fidelity_prefers_highest_feasible_rung():
    ladder = fidelity_ladder(RESNET50, UNITS, MAX_BATCH)
    # a generous SLO is feasible at full fidelity
    got = ladder.solve_with_fidelity(UNITS, 10.0)
    assert got is not None and got[0] == 0
    # an SLO only the cheapest rung can meet lands on the bottom rung
    top_floor = ladder.optimizer(0).solve(UNITS, 1).latency
    bottom_floor = ladder.optimizer(BOTTOM).solve(UNITS, 1).latency
    assert bottom_floor < top_floor
    mid_slo = 0.5 * (bottom_floor + top_floor)
    got = ladder.solve_with_fidelity(UNITS, mid_slo)
    assert got is not None and got[0] > 0
    # an SLO below every rung's floor is infeasible
    assert ladder.solve_with_fidelity(UNITS, 0.5 * bottom_floor) is None


# --------------------------------------------------------------------- #
# overload behaviour (deterministic)
# --------------------------------------------------------------------- #
def test_flash_overload_descends_ladder_before_shedding():
    loop = EventLoop()
    router = make_router(loop, 3)
    cap = 3 * node_capacity()
    offered, t_end = offer_segments(
        loop, router, [(3.0 * cap, 6.0), (0.05 * cap, 10.0)])
    loop.run_until(t_end + 30.0)
    assert_exactly_once(router)
    assert_ladder_invariants(router)
    # the flash actually drove nodes down the ladder
    events = [ev for _, _, ev in router.degrade_log]
    assert f"rung{BOTTOM}" in events
    # deliveries are rung-tagged
    assert all(r.fidelity is not None for r in router.responses)
    assert {r.fidelity for r in router.responses} >= {0, BOTTOM}


def test_recovery_climbs_rungs_in_reverse_order_under_hysteresis():
    loop = EventLoop()
    cfg = FabricConfig(p2c_seed=0, fidelity_recovery_ticks=3)
    router = make_router(loop, 3, config=cfg)
    cap = 3 * node_capacity()
    offered, t_end = offer_segments(
        loop, router, [(3.0 * cap, 6.0), (0.02 * cap, 40.0)])
    loop.run_until(t_end + 60.0)
    assert_ladder_invariants(router)
    states = replay_ladder_states(router)
    for node in router.nodes:
        snapshots = states[node.node_id]
        rungs_hit = {r for _, r, _ in snapshots}
        if BOTTOM not in rungs_hit:
            continue
        # the floor engaged at the bottom and was released before any
        # climb; the climb then walked BOTTOM -> 0 one rung at a time
        assert node.rung == 0 and not node.degraded, (
            f"{node.node_id} never recovered: rung={node.rung} "
            f"degraded={node.degraded}")
        ups = []
        prev = 0
        for _, r, _ in snapshots[1:]:
            if r < prev:
                ups.append(r)
            prev = r
        assert ups[-len(set(ups)):] == sorted(set(ups), reverse=True)
        # each climb required a full calm streak through the gate
        assert node.recovery_gate.opens >= BOTTOM
    fleet = router.fleet_report(loop.now)
    for row in fleet["fidelity"].values():
        assert row["rung"] == 0
        assert row["recovery_steps"] >= BOTTOM


def test_ladder_admits_more_than_shed_only_on_identical_trace():
    def run(ladder):
        loop = EventLoop()
        router = make_router(loop, 3, ladder=ladder)
        cap = 3 * node_capacity()
        _, t_end = offer_segments(
            loop, router, [(3.0 * cap, 6.0), (0.05 * cap, 10.0)])
        loop.run_until(t_end + 30.0)
        return router
    with_ladder = run(True)
    shed_only = run(False)
    assert with_ladder.offered == shed_only.offered
    assert len(with_ladder.sheds) < len(shed_only.sheds)
    assert len(with_ladder.responses) > len(shed_only.responses)


# --------------------------------------------------------------------- #
# overload behaviour (hypothesis traces)
# --------------------------------------------------------------------- #
if HAVE_HYPOTHESIS:
    overload_segments = st.lists(
        st.tuples(st.floats(min_value=0.05, max_value=3.5),
                  st.floats(min_value=1.0, max_value=5.0)),
        min_size=2, max_size=4)

    @given(segments=overload_segments, nodes=st.integers(1, 3),
           seed=st.integers(0, 7))
    @settings(max_examples=25, deadline=None)
    def test_no_queue_shed_while_lower_rung_feasible(segments, nodes, seed):
        loop = EventLoop()
        router = make_router(loop, nodes, seed=seed)
        cap = nodes * node_capacity()
        segs = [(mult * cap, seconds) for mult, seconds in segments]
        _, t_end = offer_segments(loop, router, segs)
        loop.run_until(t_end + 30.0)
        assert_exactly_once(router)
        assert_ladder_invariants(router)
        # every delivery carries its serving rung
        assert all(r.fidelity is not None for r in router.responses)


# --------------------------------------------------------------------- #
# fidelity-off byte-identity
# --------------------------------------------------------------------- #
def test_fidelity_off_single_model_golden_unchanged():
    server, _ = golden_run("sync")
    assert timeline_digest(single_model_timeline(server)) == GOLDEN_SHA256


def test_fidelity_off_multi_model_golden_unchanged():
    assert timeline_digest(mm_golden_run(EventLoop())) == MM_GOLDEN_SHA256


def test_fidelity_off_fabric_report_has_no_fidelity_keys():
    from repro.core.paper_profiles import PAPER_MODELS
    from repro.launch.bench_serving import run_fabric_policy
    from repro.serving.scenarios import fleet_overload_trace
    model = PAPER_MODELS["resnet50"]
    total = 3 * UNITS
    arrivals = fleet_overload_trace(
        optimizer=PackratOptimizer(model.profile(total, MAX_BATCH)),
        total_units=total, duration=6.0, seed=0,
        max_total_batch=total * MAX_BATCH)
    rep = run_fabric_policy(
        arrivals, model=model, nodes=3, units_per_node=UNITS,
        duration=6.0, seed=0, initial_batch=4, max_batch=MAX_BATCH,
        slo_deadline=1.0, reconfigure_timeout=5.0, dispatch="sync",
        engine="event", fidelity_ladder=False)
    assert "fidelity_report" not in rep
    assert "goodput_at_fidelity" not in rep
    assert "fidelity_weighted_attainment" not in rep
    assert "fidelity" not in rep["fleet"]
    for row in rep["fleet"]["per_node"].values():
        assert "fidelity_rung" not in row
    assert not any(e["event"].startswith("rung")
                   for e in rep["fleet"]["degrade_log"])
