"""Tests for active-passive scaling (paper §3.7, Fig. 5)."""

import pytest

from repro.core import (ActivePassiveController, InstanceGroup, PackratConfig,
                        Phase, needs_active_passive)


def cfg(i, t, b, lat=1.0):
    return PackratConfig(groups=(InstanceGroup(i, t, b),), latency=lat)


def make_controller(spawn=5.0, drain=1.0, swaps=None):
    return ActivePassiveController(
        spawn_cost=lambda c: spawn,
        drain_cost=lambda c: drain,
        on_swap=(swaps.append if swaps is not None else None),
    )


def test_needs_active_passive():
    # instance-count-only change -> plain worker scaling (paper case 1)
    assert not needs_active_passive(cfg(2, 4, 8), cfg(4, 4, 4))
    # per-worker thread change -> active-passive required (paper case 2)
    assert needs_active_passive(cfg(2, 4, 8), cfg(4, 2, 4))
    assert not needs_active_passive(None, cfg(1, 16, 32))


def test_three_step_transition():
    swaps = []
    ctl = make_controller(spawn=5.0, drain=2.0, swaps=swaps)
    old, new = cfg(1, 16, 32), cfg(8, 2, 4)
    ctl.start(old, now=0.0)
    done = ctl.request_reconfig(new, now=10.0)
    assert done == pytest.approx(17.0)  # 10 + 5 spawn + 2 drain
    # during scale-up the OLD config still serves: zero downtime
    assert ctl.tick(12.0) is Phase.SCALE_UP_PASSIVE
    assert ctl.serving_config == old
    assert ctl.oversubscribed            # both sets hold resources (Fig. 11 bump)
    # after spawn completes, dispatch swaps atomically
    assert ctl.tick(15.5) is Phase.DRAIN_OLD
    assert ctl.serving_config == new
    assert swaps == [new]
    # drain finishes -> stable, passive set released
    assert ctl.tick(17.5) is Phase.STABLE
    assert ctl.passive is None
    assert ctl.serving_config == new


def test_zero_downtime_invariant():
    """serving_config is never None at any instant of a reconfiguration."""
    ctl = make_controller(spawn=3.0, drain=1.0)
    ctl.start(cfg(1, 16, 64), now=0.0)
    ctl.request_reconfig(cfg(4, 4, 16), now=1.0)
    t = 0.0
    while t < 10.0:
        ctl.tick(t)
        assert ctl.serving_config is not None
        t += 0.1
    assert ctl.phase is Phase.STABLE


def test_reconfig_while_busy_rejected():
    ctl = make_controller()
    ctl.start(cfg(1, 16, 64), now=0.0)
    ctl.request_reconfig(cfg(4, 4, 16), now=1.0)
    with pytest.raises(RuntimeError):
        ctl.request_reconfig(cfg(2, 8, 32), now=2.0)
    # once stable again, new reconfigs are accepted
    ctl.tick(100.0)
    assert ctl.phase is Phase.STABLE
    ctl.request_reconfig(cfg(2, 8, 32), now=101.0)


def test_event_log_records_fig5_sequence():
    ctl = make_controller(spawn=5.0, drain=2.0)
    ctl.start(cfg(1, 16, 32), now=0.0)
    ctl.request_reconfig(cfg(8, 2, 4), now=10.0)
    ctl.tick(100.0)
    phases = [e.phase for e in ctl.events]
    assert phases == [Phase.STABLE, Phase.SCALE_UP_PASSIVE, Phase.SWAP,
                      Phase.DRAIN_OLD]


def test_start_via_request_reconfig():
    ctl = make_controller()
    done = ctl.request_reconfig(cfg(1, 4, 4), now=3.0)
    assert done == 3.0
    assert ctl.phase is Phase.STABLE
    assert ctl.serving_config == cfg(1, 4, 4)
