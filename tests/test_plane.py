"""Execution-plane tests (ISSUE 4).

* **Plane equivalence** — the refactored :class:`SimulatedPlane` engine
  reproduces the pre-refactor response timeline bit-for-bit: on the
  PR 2 golden-hash scenario (single model, full controller) and on a
  multi-model scenario whose timeline hash was captured from the
  pre-plane code at commit 3ebad30; plus a hypothesis property racing
  the plane-routed dispatcher against the verbatim pre-refactor
  ``LegacyDispatcher`` oracle.
* **RealPlane engine** — wall-clock timers, per-worker serialized
  execution, unit-budget gating, exactly-once delivery under late
  watchdogs, profiling through the plane's own runners.
* **Closed-loop calibration** — ProfileCalibrator corrections, the
  controller's optimizer refresh, and the deterministic sim-side loop
  (interference model ⇒ observed > expected ⇒ calibrated re-solve).
* **Satellite fixes** — TabulatedBackend thread interpolation and the
  JaxBackend median-of-N probe.
"""

import collections
import hashlib
import json
import math
import threading
import time

import pytest

from repro.core import PackratOptimizer
from repro.core.interference import CPUInterferenceModel
from repro.core.knapsack import InstanceGroup, PackratConfig
from repro.core.paper_profiles import INCEPTION_V3, RESNET50, PAPER_MODELS
from repro.core.profiler import (MeasuredProfiler, ProfileCalibrator,
                                 ProfileSpec, measure_latency)
from repro.serving import (CalibratedBackend, ControllerConfig, EventLoop,
                           JaxBackend, MultiModelServer, PackratServer,
                           RealPlane, Request, SimulatedPlane,
                           TabulatedBackend, TenantSpec, WorkerInstance,
                           as_plane, make_policy)
from repro.serving.dispatcher import Dispatcher, DispatcherConfig
from repro.serving.workloads import MMPPWorkload, PoissonWorkload

# shared fixtures, golden pins and drivers (one source of truth with
# test_policy.py and the fast-path differential harness)
from oracles import (GOLDEN_SHA256, MM_GOLDEN_SHA256, PROFILE,
                     TWO_GROUP_CONFIG, mm_golden_run, timeline_digest)


def test_simulated_plane_reproduces_pre_refactor_golden():
    """A PackratServer constructed over an *explicit* SimulatedPlane
    yields the exact pre-refactor response timeline (PR 2 golden)."""
    profile = INCEPTION_V3.profile(16, 1024)
    opt = PackratOptimizer(profile)
    plane = SimulatedPlane(EventLoop())
    server = PackratServer(plane, total_units=16, optimizer=opt,
                           backend=TabulatedBackend(profile),
                           initial_batch=8,
                           config=ControllerConfig(dispatch_policy="sync"))
    cfg8 = opt.solve(16, 8)
    wl = MMPPWorkload(rates=(0.5 * 8 / cfg8.latency, 2.5 * 8 / cfg8.latency),
                      mean_dwell=(5.0, 2.5))
    arrivals = wl.arrivals(30.0, seed=7)
    for i, t in enumerate(arrivals):
        plane.at(t, (lambda i=i, t=t: server.submit(Request(i, t))))
    plane.at(9.0, lambda: server.inject_failure(0))
    plane.run_until(90.0)
    timeline = [(r.request.id, round(r.completion, 9))
                for r in server.responses]
    digest = hashlib.sha256(json.dumps(timeline).encode()).hexdigest()
    assert len(timeline) == len(arrivals) == 4789
    assert digest == GOLDEN_SHA256


# --------------------------------------------------------------------- #
# plane equivalence: multi-model golden (captured pre-refactor @3ebad30;
# driver + pin shared via tests/oracles.py)
# --------------------------------------------------------------------- #
_mm_golden_run = mm_golden_run


@pytest.mark.parametrize("make_driver", [EventLoop,
                                         lambda: SimulatedPlane(EventLoop())],
                         ids=["raw-eventloop", "explicit-plane"])
def test_simulated_plane_reproduces_multimodel_golden(make_driver):
    timeline = _mm_golden_run(make_driver())
    assert timeline_digest(timeline) == MM_GOLDEN_SHA256


# --------------------------------------------------------------------- #
# plane equivalence property: routed-through-plane dispatcher vs the
# verbatim pre-refactor LegacyDispatcher oracle
# --------------------------------------------------------------------- #
def test_plane_dispatcher_matches_legacy_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    from test_policy import LegacyDispatcher, _run_dispatcher, _workers

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000),
           rate=st.floats(min_value=20.0, max_value=300.0),
           fail_at=st.one_of(st.none(), st.floats(0.2, 4.0)))
    def check(seed, rate, fail_at):
        arrivals = PoissonWorkload(rate_rps=rate).arrivals(5.0, seed=seed)
        legacy = _run_dispatcher(
            lambda loop, rs: LegacyDispatcher(
                loop, TWO_GROUP_CONFIG,
                _workers(TWO_GROUP_CONFIG, TabulatedBackend(PROFILE)),
                rs.append, DispatcherConfig(batch_timeout=0.05)),
            arrivals, fail_at)
        routed = _run_dispatcher(
            lambda loop, rs: Dispatcher(
                SimulatedPlane(loop), TWO_GROUP_CONFIG,
                _workers(TWO_GROUP_CONFIG, TabulatedBackend(PROFILE)),
                rs.append, DispatcherConfig(batch_timeout=0.05),
                policy=make_policy("sync")),
            arrivals, fail_at)
        assert routed == legacy

    check()


# --------------------------------------------------------------------- #
# RealPlane engine (fake runners: no jax needed)
# --------------------------------------------------------------------- #
def _sleep_factory(seconds=0.002):
    def make_runner(t, b):
        def run():
            time.sleep(seconds)
        return run
    return make_runner


def _flat_profile(units, batches=(1, 2, 4, 8), lat=0.002):
    return {(t, b): lat for t in range(1, units + 1) for b in batches}


def test_real_plane_timers_fire_in_order_on_wall_clock():
    plane = RealPlane(_sleep_factory(), total_units=2)
    fired = []
    plane.at(0.010, lambda: fired.append("b"))
    plane.at(0.005, lambda: fired.append("a"))
    plane.schedule(0.015, lambda: fired.append("c"))
    t0 = time.perf_counter()
    plane.run_until(0.05)
    assert fired == ["a", "b", "c"]
    assert time.perf_counter() - t0 >= 0.045
    plane.close()


def test_real_plane_dispatcher_serves_exactly_once():
    """8 requests through a real Dispatcher on sleeping workers: every
    request delivered exactly once, with wall-clock latencies."""
    profile = _flat_profile(4)
    config = PackratConfig(groups=(InstanceGroup(2, 2, 4),),
                           latency=profile[(2, 4)])
    plane = RealPlane(_sleep_factory(0.002), total_units=4)
    backend = TabulatedBackend(profile)
    workers = [WorkerInstance(j, 2, 4, backend) for j in range(2)]
    responses = []
    disp = Dispatcher(plane, config, workers, responses.append,
                      DispatcherConfig(batch_timeout=0.01))
    for i in range(8):
        plane.at(0.001 * (i + 1), (lambda i=i: disp.on_request(
            Request(i, 0.001 * (i + 1)))))
    plane.run_until(0.6)
    plane.close()
    ids = [r.request.id for r in responses]
    assert sorted(ids) == list(range(8))
    assert all(r.latency > 0 for r in responses)
    assert all(w.stats.busy_time > 0 for w in workers)


def test_real_plane_exactly_once_under_late_watchdogs():
    """Expected latencies 100x too optimistic: every batch outlives its
    straggler watchdog.  Redispatched copies must still deliver each
    request exactly once (the late-completion retirement race)."""
    profile = _flat_profile(4, lat=0.0001)       # expect 0.1ms, real ~5ms
    config = PackratConfig(groups=(InstanceGroup(2, 2, 2),),
                           latency=profile[(2, 2)])
    plane = RealPlane(_sleep_factory(0.005), total_units=4)
    backend = TabulatedBackend(profile)
    workers = [WorkerInstance(j, 2, 2, backend) for j in range(2)]
    responses = []
    disp = Dispatcher(plane, config, workers, responses.append,
                      DispatcherConfig(batch_timeout=0.005))
    n = 30
    for i in range(n):
        plane.at(0.002 * (i + 1), (lambda i=i: disp.on_request(
            Request(i, 0.002 * (i + 1)))))
    plane.run_until(1.5)
    plane.close()
    ids = [r.request.id for r in responses]
    assert len(ids) == len(set(ids)) == n, (
        f"duplicates or losses: {collections.Counter(ids).most_common(3)}")


def test_real_plane_unit_budget_bounds_concurrency():
    """Concurrently running instances never claim more than T units."""
    running = []
    peak = [0]
    lock = threading.Lock()

    def make_runner(t, b):
        def run():
            with lock:
                running.append(t)
                peak[0] = max(peak[0], sum(running))
            time.sleep(0.005)
            with lock:
                running.remove(t)
        return run

    units = 4
    profile = _flat_profile(units, lat=0.005)
    plane = RealPlane(make_runner, total_units=units)
    backend = TabulatedBackend(profile)
    # 4 two-unit workers want 8 units; the gate must cap claims at 4
    workers = [WorkerInstance(j, 2, 2, backend) for j in range(4)]
    config = PackratConfig(groups=(InstanceGroup(4, 2, 2),),
                           latency=profile[(2, 2)])
    responses = []
    disp = Dispatcher(plane, config, workers, responses.append,
                      DispatcherConfig(batch_timeout=0.002,
                                       straggler_factor=50.0))
    for i in range(32):
        plane.at(0.0005 * (i + 1), (lambda i=i: disp.on_request(
            Request(i, 0.0005 * (i + 1)))))
    plane.run_until(1.0)
    plane.close()
    assert len(responses) == 32
    assert peak[0] <= units


def test_real_plane_profiles_through_own_runners():
    """plane.profile() measures the same runner cache the serving path
    executes — one code path for profile-time and serve-time."""
    calls = collections.Counter()

    def make_runner(t, b):
        def run():
            calls[(t, b)] += 1
            time.sleep(0.0005)
        return run

    plane = RealPlane(make_runner, total_units=2)
    spec = ProfileSpec(2, 4, thread_values=(1, 2))
    profile = plane.profile(spec, warmup=1, iters=3)
    assert set(profile) == set(spec.grid())
    assert all(lat > 0 for lat in profile.values())
    assert all(calls[k] == 4 for k in spec.grid())     # warmup + iters
    # serving now reuses the profiled runner objects (same cache keys)
    runner = plane.runner(1, 3)        # b=3 rounds up to the profiled 4
    runner()
    assert calls[(1, 4)] == 5
    plane.close()


def test_real_plane_compile_ms_first_touch_only_across_evictions():
    """Re-warming an evicted cell recompiles it but must not overwrite
    (or double-count) its first-touch compile_ms entry — re-warm churn
    previously inflated per-cell compile accounting in runner_report."""
    # deterministic clock: each compile brackets two reads, so the three
    # compiles below measure 1ms, 2ms, then 500ms for the re-warm of A
    seq = [0.0, 0.001, 1.0, 1.002, 2.0, 2.5]
    clock = lambda: seq.pop(0) if seq else 100.0
    calls = []

    def make_runner(t, b):
        calls.append((t, b))
        return lambda: None

    plane = RealPlane(make_runner, total_units=2, clock=clock,
                      max_runners=1)
    plane.runner(1, 1)                 # compile A (1ms)
    assert plane.compile_ms["1,1"] == pytest.approx(1.0)
    plane.runner(1, 2)                 # compile B (2ms), evicts A
    plane.runner(1, 1)                 # re-warm A (500ms), evicts B
    plane.close()
    assert calls == [(1, 1), (1, 2), (1, 1)]     # A really recompiled
    assert plane.runner_evictions == 2
    # the 500ms recompile did not replace A's first-touch entry
    assert plane.compile_ms["1,1"] == pytest.approx(1.0)
    assert plane.compile_ms["1,2"] == pytest.approx(2.0)
    report = plane.runner_report()
    assert report["evictions"] == 2
    assert report["compile_ms"]["1,1"] == pytest.approx(1.0)


def test_real_plane_multimodel_smoke():
    """Plane-agnosticism of the tenancy layer: a two-tenant
    MultiModelServer runs end-to-end on the real plane."""
    units = 4
    profile = _flat_profile(units, lat=0.002)
    specs = [
        TenantSpec("a", profile, TabulatedBackend(profile), initial_batch=2),
        TenantSpec("b", profile, TabulatedBackend(profile), initial_batch=2),
    ]
    plane = RealPlane(_sleep_factory(0.002), total_units=units)
    ccfg = ControllerConfig()
    ccfg.estimator.max_batch = 8
    server = MultiModelServer(plane, total_units=units, tenants=specs,
                              config=ccfg, adaptive=False)
    n = 20
    for i in range(n):
        tid = "a" if i % 2 else "b"
        t = 0.004 * (i + 1)
        plane.at(t, (lambda i=i, t=t, tid=tid: server.submit(
            Request(i, t, model_id=tid))))
    plane.run_until(1.0)
    plane.close()
    ids = [r.request.id for r in server.responses]
    assert sorted(set(ids)) == list(range(n))
    by_model = collections.Counter(r.model_id for r in server.responses)
    assert by_model["a"] > 0 and by_model["b"] > 0


def test_real_plane_end_to_end_micro_mlp():
    """The acceptance path: PackratServer over RealPlane executing a
    genuine jitted micro model, profile measured through the plane,
    wall-clock latencies delivered, calibration loop populated."""
    jax = pytest.importorskip("jax")
    from repro.models.micro import make_micro_runner

    units = 2
    plane = RealPlane(make_micro_runner("mlp-tiny"), units)
    profile = plane.profile(ProfileSpec(units, 8, thread_values=(1, 2)),
                            warmup=1, iters=3)
    assert all(lat > 0 for lat in profile.values())
    opt = PackratOptimizer(profile)
    cal = ProfileCalibrator(profile, refresh_interval=0.3)
    ccfg = ControllerConfig()
    ccfg.estimator.max_batch = 8
    server = PackratServer(
        plane, total_units=units, optimizer=opt,
        backend=CalibratedBackend(TabulatedBackend(profile), cal),
        initial_batch=2, config=ccfg, calibrator=cal)
    n = 60
    for i in range(n):
        t = 0.01 * (i + 1)
        plane.at(t, (lambda i=i, t=t: server.submit(Request(i, t))))
    plane.run_until(1.8)
    plane.close()
    ids = [r.request.id for r in server.responses]
    assert len(set(ids)) == len(ids) == n
    assert all(r.latency > 0 for r in server.responses)
    assert cal.observations > 0
    rep = cal.report()
    assert rep["entries"] and rep["observations"] == cal.observations


# --------------------------------------------------------------------- #
# closed-loop calibration (deterministic, simulated plane)
# --------------------------------------------------------------------- #
def test_calibrator_learns_constant_gap_and_refreshes():
    base = {(1, 1): 0.010, (1, 2): 0.020, (2, 2): 0.012}
    cal = ProfileCalibrator(base, rel_threshold=0.10, refresh_interval=1.0,
                            min_samples=3)
    assert cal.correction(1, 1) == 1.0 and not cal.should_refresh(10.0)
    for _ in range(20):
        cal.observe(1, 1, 0.015)         # 1.5x the expected 10ms
    assert cal.correction(1, 1) == pytest.approx(1.5, rel=1e-3)
    # unobserved cells borrow the global ratio
    assert cal.correction(2, 2) == pytest.approx(1.5, rel=1e-3)
    calibrated = cal.calibrated_profile()
    assert calibrated[(1, 1)] == pytest.approx(0.015, rel=1e-3)
    assert cal.should_refresh(10.0)
    cal.mark_refreshed(10.0)
    assert not cal.should_refresh(10.5)      # interval not elapsed
    assert not cal.should_refresh(20.0)      # no drift since refresh
    rep = cal.report()
    assert rep["refreshes"] == 1 and rep["observations"] == 20
    assert rep["entries"][0]["ratio"] == pytest.approx(1.5, rel=1e-3)


def test_calibrator_maps_partial_batches_to_profiled_cell():
    base = {(1, 4): 0.010}
    cal = ProfileCalibrator(base, min_samples=1)
    cal.observe(1, 3, 0.020)       # partial batch of 3 -> the b=4 cell
    assert cal.correction(1, 4) == pytest.approx(2.0, rel=1e-3)
    assert cal.correction_at(1, 3) == pytest.approx(2.0, rel=1e-3)


def test_calibrator_rejects_garbage_observations():
    cal = ProfileCalibrator({(1, 1): 0.010}, min_samples=1)
    cal.observe(1, 1, float("nan"))
    cal.observe(1, 1, -1.0)
    cal.observe(1, 1, 0.0)
    assert cal.observations == 0 and cal.correction(1, 1) == 1.0
    cal.observe(1, 1, 1e9)         # clamped, not believed verbatim
    assert cal.correction(1, 1) <= 16.0


def test_sim_interference_gap_closes_via_optimizer_refresh():
    """Deterministic closed loop: the interference model makes observed
    latencies exceed the isolated profile; the calibrator must learn a
    ratio > 1 and the tenant must rebuild its optimizer against the
    calibrated (inflated) costs."""
    profile = INCEPTION_V3.profile(8, 256)
    opt = PackratOptimizer(profile)
    cal = ProfileCalibrator(profile, rel_threshold=0.05,
                            refresh_interval=2.0)
    loop = EventLoop()
    backend = TabulatedBackend(profile,
                               interference=CPUInterferenceModel(),
                               total_units=8)
    ccfg = ControllerConfig()
    ccfg.estimator.max_batch = 256
    server = PackratServer(loop, total_units=8, optimizer=opt,
                           backend=backend, initial_batch=8,
                           config=ccfg, calibrator=cal)
    cfg8 = opt.solve(8, 8)
    rate = 0.7 * 8 / cfg8.latency
    for i in range(int(rate * 30)):
        t = (i + 1) / rate
        loop.at(t, (lambda i=i, t=t: server.submit(Request(i, t))))
    loop.run_until(60.0)
    assert cal.observations > 0
    assert cal.global_ratio > 1.05          # the Fig. 9 gap, measured
    assert server.calibration_refreshes >= 1
    # the refreshed optimizer plans against inflated (calibrated) costs,
    # not the isolated profile (corrections keep moving after the
    # refresh, so compare against base rather than the live table)
    key = next(iter(profile))
    assert server.optimizer.profile[key] > profile[key]
    # and the run is deterministic: same responses on a re-run
    assert len(server.responses) > 0


def test_calibration_is_off_by_default_and_sim_stays_golden():
    """No calibrator => no on_measure hook, no optimizer swap: the
    golden path above already pins this, here we assert the wiring."""
    profile = INCEPTION_V3.profile(8, 64)
    loop = EventLoop()
    server = PackratServer(loop, total_units=8,
                           optimizer=PackratOptimizer(profile),
                           backend=TabulatedBackend(profile),
                           initial_batch=8)
    assert server.calibrator is None
    assert server.dispatcher.on_measure is None
    assert server.calibration_refreshes == 0


# --------------------------------------------------------------------- #
# satellite: TabulatedBackend thread-count interpolation
# --------------------------------------------------------------------- #
def test_tabulated_backend_interpolates_between_thread_rows():
    table = {(2, 4): 0.100, (8, 4): 0.040}
    be = TabulatedBackend(table)
    # t=4 sits a third of the way from 2 to 8
    assert be.batch_latency(4, 4) == pytest.approx(
        0.100 + (4 - 2) / (8 - 2) * (0.040 - 0.100))
    assert be.batch_latency(5, 4) == pytest.approx(0.070)
    assert be.fallback_lookups[(4, 4)] == 1
    rep = be.fallback_report()
    assert rep["count"] == 2
    assert {(k["t"], k["b"]) for k in rep["keys"]} == {(4, 4), (5, 4)}


def test_tabulated_backend_clamps_outside_thread_range():
    table = {(2, 4): 0.100, (8, 4): 0.040}
    be = TabulatedBackend(table)
    assert be.batch_latency(1, 4) == pytest.approx(0.100)    # below -> t=2
    assert be.batch_latency(16, 4) == pytest.approx(0.040)   # above -> t=8
    assert be.fallback_report()["count"] == 2


def test_tabulated_backend_exact_rows_never_count_fallbacks():
    be = TabulatedBackend(PROFILE)
    be.batch_latency(4, 8)
    be.batch_latency(4, 3)       # partial batch: same row, rounds b up
    assert be.fallback_report()["count"] == 0


# --------------------------------------------------------------------- #
# satellite: shared measurement helper (JaxBackend median-of-N)
# --------------------------------------------------------------------- #
def test_measure_latency_median_is_outlier_robust():
    durations = iter([1.0, 1.0, 1.0, 50.0, 1.0])   # one GC-pause outlier
    clock_now = [0.0]

    def clock():
        return clock_now[0]

    def run():
        clock_now[0] += next(durations)

    lat = measure_latency(run, warmup=0, iters=5, clock=clock, median=True)
    assert lat == 1.0                  # median; the mean would be 10.8


def test_measured_profiler_mean_methodology_unchanged():
    ticks = [0.0]

    def clock():
        return ticks[0]

    def runner(t, b):
        ticks[0] += 0.010

    prof = MeasuredProfiler(runner, warmup=2, iters=5, clock=clock)
    assert prof.measure(1, 1) == pytest.approx(0.010)


def test_jax_backend_probe_uses_warmup_plus_median():
    calls = collections.Counter()

    def make_runner(b):
        def run():
            calls[b] += 1
        return run

    be = JaxBackend(make_runner, warmup=2, iters=5)
    lat_first = be.batch_latency(1, 3)          # rounds b up to 4
    assert calls[4] == 7                        # warmup + iters, once
    assert be.batch_latency(1, 4) == lat_first  # cached, no re-run
    assert calls[4] == 7
    assert lat_first >= 0.0
