"""Profiler, roofline-model and interference tests."""

import math

import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.core import (CPUInterferenceModel, MeasuredProfiler,
                        PackratOptimizer, ProfileSpec, RooflineTerms,
                        TPU_V5E, TPUInterferenceModel, apply_constant_penalty,
                        profiling_cost_summary)
from repro.core.knapsack import InstanceGroup, PackratConfig


# --------------------------------------------------------------------- #
# profiler grid (§3.2)
# --------------------------------------------------------------------- #
def test_profile_spec_grid_size():
    spec = ProfileSpec(total_threads=16, max_batch=1024)
    assert spec.n_configs == 16 * 11          # (n+1)·T with n=10
    assert spec.n_exhaustive == 16 * 1024     # 2^n·T
    s = profiling_cost_summary(spec)
    assert s["reduction"] == pytest.approx(1024 / 11, rel=1e-6)


def test_measured_profiler_counts_calls():
    calls = []
    clock = iter(float(i) for i in range(10_000))

    def runner(t, b):
        calls.append((t, b))

    prof = MeasuredProfiler(runner, warmup=2, iters=3,
                            clock=lambda: next(clock))
    spec = ProfileSpec(total_threads=2, max_batch=4)
    table = prof.profile(spec)
    assert set(table) == {(1, 1), (1, 2), (1, 4), (2, 1), (2, 2), (2, 4)}
    assert len(calls) == 6 * 5                 # warmup+iters per config
    assert all(v > 0 for v in table.values())


# --------------------------------------------------------------------- #
# roofline terms
# --------------------------------------------------------------------- #
def test_roofline_terms_math():
    terms = RooflineTerms(flops=197e12 * 4, hbm_bytes=819e9 * 2,
                          collective_bytes=50e9 * 4, chips=4, hw=TPU_V5E)
    assert terms.compute_s == pytest.approx(1.0)
    assert terms.memory_s == pytest.approx(0.5)
    assert terms.collective_s == pytest.approx(1.0)   # 4 links × 50 GB/s
    assert terms.dominant in ("compute", "collective")
    assert terms.latency == pytest.approx(1.0 + TPU_V5E.dispatch_overhead)
    assert terms.latency_serial > terms.latency


def test_roofline_fraction_counts_useful_flops():
    terms = RooflineTerms(flops=2e12, hbm_bytes=1, collective_bytes=0,
                          chips=1, hw=TPU_V5E)
    full = terms.roofline_fraction()
    useful = terms.roofline_fraction(model_flops=1e12)
    assert useful == pytest.approx(full / 2, rel=1e-6)
    assert 0 < useful <= 1.0


@given(flops=st.floats(1e9, 1e18), hbm=st.floats(1e6, 1e15),
       coll=st.floats(0, 1e13), chips=st.sampled_from([1, 8, 256]))
@settings(max_examples=30, deadline=None)
def test_roofline_latency_is_max_term(flops, hbm, coll, chips):
    terms = RooflineTerms(flops=flops, hbm_bytes=hbm, collective_bytes=coll,
                          chips=chips)
    assert terms.latency == pytest.approx(
        max(terms.compute_s, terms.memory_s, terms.collective_s)
        + TPU_V5E.dispatch_overhead)


# --------------------------------------------------------------------- #
# interference models (§5.2.2)
# --------------------------------------------------------------------- #
def test_cpu_interference_monotone():
    m = CPUInterferenceModel()
    assert m.downclock_factor(0, 16) == pytest.approx(1.0)
    assert m.downclock_factor(16, 16) == pytest.approx(2.6 / 2.2)
    assert m.memory_factor(1) == pytest.approx(1.0)
    assert m.memory_factor(16) > m.memory_factor(4) >= 1.0


def test_cpu_interference_fig9_magnitudes():
    """Fig. 9: full downclock ≈ +15%/core clock; combined gap ~30-40%."""
    m = CPUInterferenceModel()
    cfg = PackratConfig(groups=(InstanceGroup(16, 1, 16),), latency=1.224)
    slow = m.slowdown(cfg, 16)
    assert 1.25 < slow < 1.5


def test_tpu_interference_negligible():
    m = TPUInterferenceModel()
    cfg = PackratConfig(groups=(InstanceGroup(16, 16, 8),), latency=1.0)
    assert m.slowdown(cfg, 256) < 1.06


def test_constant_penalty_validation():
    with pytest.raises(ValueError):
        apply_constant_penalty({(1, 1): 1.0}, 0.0)
    scaled = apply_constant_penalty({(1, 1): 2.0}, 0.5)
    assert scaled == {(1, 1): 1.0}


# --------------------------------------------------------------------- #
# the TPU L(t,b) profile drives the DP sensibly
# --------------------------------------------------------------------- #
def test_tpu_profile_feeds_knapsack():
    """Synthetic decode-like profile: collective floor ⇒ thin instances win."""
    def L(t, b):
        compute = 1e-3 * b / t
        collective = 5e-3 * math.log2(max(2, t))   # grows with group size
        overhead = 5e-5
        return max(compute, collective) + overhead

    table = {(t, b): L(t, b)
             for t in (8, 16, 32, 64, 128, 256)
             for b in (1, 4, 16, 64)}
    opt = PackratOptimizer(table)
    cfg = opt.solve(256, 64)
    fat = table[(256, 64)]
    assert cfg.latency < fat                 # partitioning beats fat pod
    assert all(g.t < 256 for g in cfg.groups)
