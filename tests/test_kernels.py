"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode).

Each Pallas kernel is swept over shapes/dtypes and asserted against
repro.kernels.ref; the SSD *chunked* model path is additionally asserted
against the sequential-recurrence reference.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

jax.config.update("jax_enable_x64", False)


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


def _assert_close(got, want, dtype):
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


# --------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,Hkv,D", [
    (1, 64, 4, 4, 32),      # MHA
    (2, 128, 4, 2, 32),     # GQA
    (1, 96, 8, 1, 16),      # MQA, ragged seq (padding path)
    (2, 256, 2, 2, 64),
])
def test_flash_attention_causal(B, S, H, Hkv, D, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (B, S, H, D), dtype)
    k = jax.random.normal(k2, (B, S, Hkv, D), dtype)
    v = jax.random.normal(k3, (B, S, Hkv, D), dtype)
    got = ops.flash_attention(q, k, v, causal=True, block_q=32, block_kv=32)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    _assert_close(got, want, dtype)


@pytest.mark.parametrize("window", [16, 48, 100])
def test_flash_attention_window(window):
    B, S, H, Hkv, D = 2, 128, 4, 1, 32
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(k1, (B, S, H, D))
    k = jax.random.normal(k2, (B, S, Hkv, D))
    v = jax.random.normal(k3, (B, S, Hkv, D))
    got = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=32, block_kv=32)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    _assert_close(got, want, jnp.float32)


def test_flash_attention_matches_model_blocked_path():
    """The model's jnp blocked attention and the kernel agree."""
    from repro.models.common import blocked_attention
    B, S, H, Hkv, D = 1, 128, 4, 2, 32
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(k1, (B, S, H, D))
    k = jax.random.normal(k2, (B, S, Hkv, D))
    v = jax.random.normal(k3, (B, S, Hkv, D))
    got = ops.flash_attention(q, k, v, causal=True, block_q=32, block_kv=32)
    want = blocked_attention(q, k, v, causal=True, block_q=32, block_kv=32)
    _assert_close(got, want, jnp.float32)


# --------------------------------------------------------------------- #
# decode attention
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,Hkv,D", [
    (2, 128, 4, 2, 32),
    (1, 256, 8, 8, 64),
    (3, 96, 4, 1, 16),      # ragged cache length (padding path)
])
def test_decode_attention(B, S, H, Hkv, D, dtype):
    keys = jax.random.split(jax.random.PRNGKey(3), 4)
    q = jax.random.normal(keys[0], (B, 1, H, D), dtype)
    kc = jax.random.normal(keys[1], (B, S, Hkv, D), dtype)
    vc = jax.random.normal(keys[2], (B, S, Hkv, D), dtype)
    lengths = jax.random.randint(keys[3], (B,), 1, S + 1)
    got = ops.decode_attention(q, kc, vc, lengths, block_kv=32)
    want = ref.decode_attention_ref(q, kc, vc, lengths)
    _assert_close(got, want, dtype)


def test_decode_attention_matches_model_decode():
    """Model decode_attention (full cache) == kernel at length = pos+1."""
    from repro.models.common import decode_attention as model_decode
    B, S, H, Hkv, D = 2, 64, 4, 2, 32
    keys = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(keys[0], (B, 1, H, D))
    kc = jax.random.normal(keys[1], (B, S, Hkv, D))
    vc = jax.random.normal(keys[2], (B, S, Hkv, D))
    pos = 37
    got = ops.decode_attention(q, kc, vc, jnp.full((B,), pos + 1), block_kv=32)
    want = model_decode(q, kc, vc, pos)
    _assert_close(got, want, jnp.float32)


# --------------------------------------------------------------------- #
# SSD scan
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,P,G,N,chunk", [
    (1, 64, 2, 8, 1, 16, 16),
    (2, 128, 4, 16, 1, 32, 32),
    (1, 64, 4, 8, 2, 16, 16),    # grouped B/C
])
def test_ssd_scan(B, S, H, P, G, N, chunk, dtype):
    keys = jax.random.split(jax.random.PRNGKey(5), 5)
    x = jax.random.normal(keys[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(keys[1], (B, S, H))).astype(jnp.float32)
    a_log = jnp.log(jnp.linspace(1.0, 4.0, H))
    B_in = jax.random.normal(keys[2], (B, S, G, N), dtype)
    C_in = jax.random.normal(keys[3], (B, S, G, N), dtype)
    got = ops.ssd_scan(x, dt, a_log, B_in, C_in, chunk=chunk)
    want, _ = ref.ssd_scan_ref(x, dt, a_log, B_in, C_in)
    _assert_close(got, want, dtype)


def test_ssd_chunked_model_path_matches_sequential():
    """The model's chunked SSD == sequential recurrence, incl. final state."""
    B, S, H, P, G, N = 2, 96, 3, 8, 1, 16
    keys = jax.random.split(jax.random.PRNGKey(6), 4)
    x = jax.random.normal(keys[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(keys[1], (B, S, H)))
    a_log = jnp.log(jnp.linspace(1.0, 4.0, H))
    B_in = jax.random.normal(keys[2], (B, S, G, N))
    C_in = jax.random.normal(keys[3], (B, S, G, N))
    got_y, got_h = ref.ssd_chunked_ref(x, dt, a_log, B_in, C_in, chunk=16)
    want_y, want_h = ref.ssd_scan_ref(x, dt, a_log, B_in, C_in)
    _assert_close(got_y, want_y, jnp.float32)
    _assert_close(got_h, want_h, jnp.float32)


# --------------------------------------------------------------------- #
# RG-LRU scan
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("B,S,W,bs,bw", [
    (1, 64, 16, 16, 16),
    (2, 128, 48, 32, 16),
    (1, 96, 32, 32, 32),
])
def test_rglru_scan(B, S, W, bs, bw):
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    a = jax.nn.sigmoid(jax.random.normal(k1, (B, S, W)))
    b = jax.random.normal(k2, (B, S, W))
    got = ops.rglru_scan(a, b, block_s=bs, block_w=bw)
    want, _ = ref.rglru_scan_ref(a, b)
    _assert_close(got, want, jnp.float32)


def test_rglru_assoc_scan_matches_sequential():
    """models.rglru associative scan == sequential reference."""
    import jax.numpy as jnp
    from repro.models.rglru import rglru_gates

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    k1, k2 = jax.random.split(jax.random.PRNGKey(8))
    a = jax.nn.sigmoid(jax.random.normal(k1, (2, 64, 8)))
    b = jax.random.normal(k2, (2, 64, 8))
    _, h_assoc = jax.lax.associative_scan(combine, (a, b), axis=1)
    h_seq, _ = ref.rglru_scan_ref(a, b)
    _assert_close(h_assoc, h_seq, jnp.float32)

# --------------------------------------------------------------------- #
# decode attention: argument validation (PR 9 satellite)
# --------------------------------------------------------------------- #
def test_decode_attention_validates_arguments():
    B, S, H, Hkv, D = 2, 64, 4, 2, 32
    keys = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(keys[0], (B, 1, H, D))
    kc = jax.random.normal(keys[1], (B, S, Hkv, D))
    vc = jax.random.normal(keys[2], (B, S, Hkv, D))
    lengths = jnp.full((B,), S)
    cases = [
        (dict(q=q[:, 0]), "must be \\(B, 1, H, D\\)"),            # 3-D q
        (dict(q=jnp.repeat(q, 2, axis=1)), "must be \\(B, 1, H, D\\)"),
        (dict(vc=vc[:, : S // 2]), "shapes differ"),
        (dict(q=q[:1]), "batch mismatch"),
        (dict(q=q[..., : D // 2]), "head dim mismatch"),
        (dict(kc=kc[:, :, :1], vc=vc[:, :, :1]),                  # Hkv=1 ok;
         None),                                                   # MQA valid
        (dict(kc=kc[:, :, :, :].repeat(3, axis=2),
              vc=vc[:, :, :, :].repeat(3, axis=2)), "multiple"),  # Hkv=6 > H? no, 6 not divisor of 4
        (dict(q=q.astype(jnp.bfloat16)), "dtype mismatch"),
        (dict(lengths=jnp.full((B, 1), S)), "lengths must be"),
    ]
    for override, match in cases:
        kw = dict(q=q, kc=kc, vc=vc, lengths=lengths)
        kw.update(override)
        if match is None:
            ops.decode_attention(kw["q"], kw["kc"], kw["vc"], kw["lengths"],
                                 block_kv=32)
            continue
        with pytest.raises(ValueError, match=match):
            ops.decode_attention(kw["q"], kw["kc"], kw["vc"], kw["lengths"],
                                 block_kv=32)


def test_decode_attention_rejects_unpadded_cache_length():
    from repro.kernels.decode_attention import decode_attention as raw
    B, S, H, Hkv, D = 1, 48, 2, 1, 16
    keys = jax.random.split(jax.random.PRNGKey(8), 3)
    q = jax.random.normal(keys[0], (B, 1, H, D))
    kc = jax.random.normal(keys[1], (B, S, Hkv, D))
    vc = jax.random.normal(keys[2], (B, S, Hkv, D))
    with pytest.raises(ValueError, match="multiple of\\s+block_kv"):
        raw(q, kc, vc, jnp.full((B,), S), block_kv=32, interpret=True)
