"""Quickstart: Packrat's optimizer end-to-end in 60 seconds.

Profiles a model (paper-calibrated ResNet-50 curve), solves the 2-D
knapsack for several batch sizes, and prints the chosen ⟨i,t,b⟩
configurations with their predicted speedups over the fat instance —
the paper's core loop (§3.2-§3.3) with zero hardware requirements.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import PackratOptimizer, fat_config
from repro.core.paper_profiles import RESNET50

T = 16           # threads on one socket (paper Table 1)

# 1. profile ⟨1,t,b⟩ single-instance latencies (here: calibrated model;
#    swap in MeasuredProfiler/AnalyticProfiler for real hardware)
profile = RESNET50.profile(T, max_batch=1024)
print(f"profiled {len(profile)} single-instance configurations "
      f"(the paper's (n+1)·T grid)")

# 2. solve the 2-D knapsack per batch size
opt = PackratOptimizer(profile)
print(f"\n{'B':>5} {'packrat config':<24} {'latency':>9} "
      f"{'fat latency':>11} {'speedup':>8}")
for B in (8, 16, 32, 64, 128, 256, 512, 1024):
    cfg = opt.solve(T, B)
    fat = fat_config(profile, T, B)
    print(f"{B:5d} {' '.join(str(g) for g in cfg.groups):<24}"
          f"{cfg.latency * 1e3:8.1f}ms {fat.latency * 1e3:10.1f}ms "
          f"{fat.latency / cfg.latency:7.2f}x")

# 3. non-power-of-two deployments mix instance types (§5.2.3)
opt14 = PackratOptimizer(RESNET50.profile(14, max_batch=1024))
cfg = opt14.solve(14, 256)
print(f"\nT=14, B=256 → {' '.join(str(g) for g in cfg.groups)} "
      f"(non-uniform split, Σi·t={cfg.total_threads}, "
      f"Σi·b={cfg.total_batch})")
