"""Train a ~100M-parameter llama-style model for a few hundred steps.

The end-to-end training driver: synthetic (learnable) corpus, AdamW with
warmup+cosine, periodic checkpointing with the fault-tolerant commit
protocol, and resumption.  At d_model=512, 8 layers, vocab 32768 the
model is ~101M params — big enough to be real, small enough for CPU.

Run:  PYTHONPATH=src python examples/train_small.py [--steps 300]
"""

import argparse
import sys

from repro.launch.train import main


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt_100m")
    args = ap.parse_args()
    sys.exit(main([
        "--arch", "llama3-8b", "--reduced",
        "--d-model", "512", "--layers", "8", "--vocab", "32768",
        "--steps", str(args.steps), "--batch", "8", "--seq", "256",
        "--lr", "1e-3", "--ckpt", args.ckpt, "--ckpt-every", "100",
        "--resume", "--log-every", "20",
    ]))
