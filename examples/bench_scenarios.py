"""Scenario benchmark in 30 seconds: adaptive Packrat vs a static fat
instance under a diurnal load curve.

Drives the full controller (estimator → knapsack → allocator →
active-passive reconfig → dispatcher) on the deterministic event loop
and prints the JSON report.  Swap ``diurnal`` for any name printed by
``--list`` (bursty MMPP, Fig.-11 steps, ramps, flash-crowd trace
replay), or replay your own trace with ``--trace my_trace.json``.

Run:  PYTHONPATH=src python examples/bench_scenarios.py
"""

import sys

from repro.launch.bench_serving import main

if __name__ == "__main__":
    sys.exit(main(["--scenario", "diurnal", "--duration", "30"]))
