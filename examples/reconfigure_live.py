"""Active-passive reconfiguration, observed live (paper Fig. 5 + Fig. 11).

Runs the serving simulator with the paper-calibrated Inception-v3
profile, steps the request rate at t=8 s, and prints a per-second
latency timeline annotated with the controller's phase transitions —
the zero-downtime property is visible directly: completions continue
through SCALE_UP_PASSIVE → SWAP → DRAIN_OLD.

Run:  PYTHONPATH=src python examples/reconfigure_live.py
"""

import collections
import pathlib
import statistics
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.fig11_reconfig import run_timeline  # noqa: E402


def main() -> int:
    server, arrivals = run_timeline(duration=40.0)
    by_s = collections.defaultdict(list)
    for r in server.responses:
        by_s[int(r.request.arrival)].append(r.latency)
    events = {int(t): f"  <-- reconfig to B={b}: "
              f"{' '.join(str(g) for g in c.groups)}"
              for t, b, c in server.reconfig_log if t > 0}
    print(f"{'t':>4} {'median latency':>15}")
    for s in sorted(by_s):
        med = statistics.median(by_s[s]) * 1e3
        bar = "#" * min(60, int(med / 25))
        print(f"{s:3d}s {med:12.0f}ms {bar}{events.get(s, '')}")
    print(f"\ncompleted {len(server.responses)}/{len(arrivals)} "
          f"requests; reconfigurations: {len(server.reconfig_log) - 1}; "
          f"active-passive events: "
          f"{[e.phase.value for e in server.apc.events]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
