"""Online serving end-to-end: real JAX inference + live reconfiguration.

Drives the full Packrat stack (estimator → optimizer → allocator →
dispatcher → workers) with *measured* latencies from a genuine jitted
decode step of a reduced gemma3 model, under a request rate that steps
up mid-run — the paper's Fig. 11 experiment against real model code.

Run:  PYTHONPATH=src python examples/serve_online.py
"""

import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.exit(main(["--arch", "gemma3-1b", "--duration", "16",
                   "--rate-step", "8", "--initial-batch", "8",
                   "--max-batch", "32"]))
